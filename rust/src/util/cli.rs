//! Declarative command-line parsing (clap replacement).
//!
//! Supports `--flag value`, `--flag=value`, boolean switches, defaults,
//! required flags, typed accessors, subcommands, and generated `--help`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    required: bool,
    is_switch: bool,
}

/// A flag-set specification for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Spec {
    command: String,
    about: String,
    flags: Vec<FlagSpec>,
}

impl Spec {
    pub fn new(command: &str, about: &str) -> Spec {
        Spec {
            command: command.to_string(),
            about: about.to_string(),
            flags: Vec::new(),
        }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Spec {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            required: false,
            is_switch: false,
        });
        self
    }

    /// `--name <value>`, required.
    pub fn req(mut self, name: &str, help: &str) -> Spec {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            required: true,
            is_switch: false,
        });
        self
    }

    /// Boolean `--name` switch (defaults to false).
    pub fn switch(mut self, name: &str, help: &str) -> Spec {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            required: false,
            is_switch: true,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nFLAGS:\n", self.command, self.about);
        for f in &self.flags {
            let val = if f.is_switch { "" } else { " <value>" };
            let def = match (&f.default, f.is_switch) {
                (Some(d), false) => format!(" [default: {d}]"),
                _ if f.required => " [required]".to_string(),
                _ => String::new(),
            };
            s.push_str(&format!("  --{}{val}\n      {}{def}\n", f.name, f.help));
        }
        s
    }

    /// Parse argv (not including the program/subcommand name).
    pub fn parse(&self, args: &[String]) -> Result<Args, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(CliError(self.help_text()));
            }
            let stripped = a
                .strip_prefix("--")
                .ok_or_else(|| CliError(format!("unexpected argument '{a}'")))?;
            let (name, inline_val) = match stripped.split_once('=') {
                Some((n, v)) => (n.to_string(), Some(v.to_string())),
                None => (stripped.to_string(), None),
            };
            let spec = self
                .flags
                .iter()
                .find(|f| f.name == name)
                .ok_or_else(|| CliError(format!("unknown flag '--{name}'")))?;
            let value = if spec.is_switch {
                match inline_val {
                    Some(v) => v,
                    None => "true".to_string(),
                }
            } else {
                match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        args.get(i)
                            .cloned()
                            .ok_or_else(|| CliError(format!("flag '--{name}' needs a value")))?
                    }
                }
            };
            values.insert(name, value);
            i += 1;
        }
        for f in &self.flags {
            if !values.contains_key(&f.name) {
                match &f.default {
                    Some(d) => {
                        values.insert(f.name.clone(), d.clone());
                    }
                    None if f.required => {
                        return Err(CliError(format!("missing required flag '--{}'", f.name)))
                    }
                    None => {}
                }
            }
        }
        Ok(Args { values })
    }
}

/// Parsed flag values with typed accessors.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
}

impl Args {
    /// Value of a flag if it was declared in the Spec (None otherwise).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag '--{name}' not declared in Spec"))
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected unsigned integer, got '{}'", self.str(name))))
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected u64, got '{}'", self.str(name))))
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected float, got '{}'", self.str(name))))
    }

    pub fn bool(&self, name: &str) -> Result<bool, CliError> {
        match self.str(name) {
            "true" | "1" | "yes" => Ok(true),
            "false" | "0" | "no" => Ok(false),
            other => Err(CliError(format!("--{name}: expected bool, got '{other}'"))),
        }
    }

    /// Comma-separated list of usize.
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| CliError(format!("--{name}: bad list element '{s}'")))
            })
            .collect()
    }

    /// Comma-separated list of f64.
    pub fn f64_list(&self, name: &str) -> Result<Vec<f64>, CliError> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| CliError(format!("--{name}: bad list element '{s}'")))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    fn spec() -> Spec {
        Spec::new("demo", "test spec")
            .opt("n", "100", "rows")
            .req("out", "output path")
            .switch("verbose", "chatty")
            .opt("ps", "10,20", "p values")
    }

    #[test]
    fn defaults_and_required() {
        let a = spec().parse(&strs(&["--out", "/tmp/x"])).unwrap();
        assert_eq!(a.usize("n").unwrap(), 100);
        assert_eq!(a.str("out"), "/tmp/x");
        assert!(!a.bool("verbose").unwrap());
    }

    #[test]
    fn equals_syntax() {
        let a = spec().parse(&strs(&["--out=/o", "--n=42"])).unwrap();
        assert_eq!(a.usize("n").unwrap(), 42);
        assert_eq!(a.str("out"), "/o");
    }

    #[test]
    fn switch_toggles() {
        let a = spec().parse(&strs(&["--out", "x", "--verbose"])).unwrap();
        assert!(a.bool("verbose").unwrap());
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&strs(&[])).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        let e = spec().parse(&strs(&["--out", "x", "--bogus", "1"]));
        assert!(e.is_err());
        assert!(format!("{}", e.unwrap_err()).contains("bogus"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(spec().parse(&strs(&["--out"])).is_err());
    }

    #[test]
    fn lists_parse() {
        let a = spec()
            .parse(&strs(&["--out", "x", "--ps", "1, 2,3"]))
            .unwrap();
        assert_eq!(a.usize_list("ps").unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn bad_number_reports_flag() {
        let a = spec().parse(&strs(&["--out", "x", "--n", "abc"])).unwrap();
        let e = a.usize("n").unwrap_err();
        assert!(format!("{e}").contains("--n"));
    }

    #[test]
    fn help_contains_flags() {
        let h = spec().help_text();
        assert!(h.contains("--out"));
        assert!(h.contains("[default: 100]"));
        assert!(h.contains("[required]"));
    }

    #[test]
    fn help_flag_short_circuits() {
        let e = spec().parse(&strs(&["--help"])).unwrap_err();
        assert!(e.0.contains("FLAGS"));
    }
}
