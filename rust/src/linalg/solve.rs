//! Triangular solves — used to apply L⁻¹ / L⁻ᵀ in Algorithm 1 line 21
//! (`F ← La⁻ᵀ F Lb⁻¹`) and in the Horst baseline's approximate LS solves.

use super::mat::Mat;

/// Solve L·X = B for X, with L lower triangular (forward substitution),
/// column-blocked over B.
pub fn solve_lower(l: &Mat, b: &Mat) -> Mat {
    assert_eq!(l.rows, l.cols);
    assert_eq!(l.rows, b.rows);
    let n = l.rows;
    let m = b.cols;
    let mut x = b.clone();
    for i in 0..n {
        let lii = l[(i, i)];
        assert!(lii != 0.0, "singular triangular factor at {i}");
        for k in 0..i {
            let lik = l[(i, k)];
            if lik == 0.0 {
                continue;
            }
            // x[i,:] -= l[i,k] * x[k,:]
            let (head, tail) = x.data.split_at_mut(i * m);
            let xk = &head[k * m..(k + 1) * m];
            let xi = &mut tail[..m];
            for (a, b) in xi.iter_mut().zip(xk) {
                *a -= lik * b;
            }
        }
        for v in x.row_mut(i) {
            *v /= lii;
        }
    }
    x
}

/// Solve Lᵀ·X = B for X, with L lower triangular (back substitution on Lᵀ).
pub fn solve_lower_transpose(l: &Mat, b: &Mat) -> Mat {
    assert_eq!(l.rows, l.cols);
    assert_eq!(l.rows, b.rows);
    let n = l.rows;
    let m = b.cols;
    let mut x = b.clone();
    for i in (0..n).rev() {
        let lii = l[(i, i)];
        assert!(lii != 0.0, "singular triangular factor at {i}");
        for k in (i + 1)..n {
            let lki = l[(k, i)]; // (Lᵀ)[i,k]
            if lki == 0.0 {
                continue;
            }
            let (head, tail) = x.data.split_at_mut(k * m);
            let xi = &mut head[i * m..(i + 1) * m];
            let xk = &tail[..m];
            for (a, b) in xi.iter_mut().zip(xk) {
                *a -= lki * b;
            }
        }
        for v in x.row_mut(i) {
            *v /= lii;
        }
    }
    x
}

/// Solve U·X = B for X, with U upper triangular.
pub fn solve_upper(u: &Mat, b: &Mat) -> Mat {
    assert_eq!(u.rows, u.cols);
    assert_eq!(u.rows, b.rows);
    let n = u.rows;
    let m = b.cols;
    let mut x = b.clone();
    for i in (0..n).rev() {
        let uii = u[(i, i)];
        assert!(uii != 0.0, "singular triangular factor at {i}");
        for k in (i + 1)..n {
            let uik = u[(i, k)];
            if uik == 0.0 {
                continue;
            }
            let (head, tail) = x.data.split_at_mut(k * m);
            let xi = &mut head[i * m..(i + 1) * m];
            let xk = &tail[..m];
            for (a, b) in xi.iter_mut().zip(xk) {
                *a -= uik * b;
            }
        }
        for v in x.row_mut(i) {
            *v /= uii;
        }
    }
    x
}

/// Solve (L·Lᵀ)·X = B given the Cholesky factor L (SPD solve).
pub fn solve_chol(l: &Mat, b: &Mat) -> Mat {
    solve_lower_transpose(l, &solve_lower(l, b))
}

/// X·L⁻¹ for lower-triangular L, i.e. solve X_out · L = X ⇔ Lᵀ·X_outᵀ = Xᵀ.
/// Used for `F Lb⁻¹` in Algorithm 1 line 21.
pub fn right_solve_lower(x: &Mat, l: &Mat) -> Mat {
    solve_lower_transpose(l, &x.transpose()).transpose()
}

/// X·L⁻ᵀ for lower-triangular L, i.e. solve X_out · Lᵀ = X ⇔ L·X_outᵀ = Xᵀ.
/// This is Algorithm 1's `F Lb⁻¹` under the Matlab upper-Cholesky
/// convention (paper's L is our Lᵀ).
pub fn right_solve_lower_transpose(x: &Mat, l: &Mat) -> Mat {
    solve_lower(l, &x.transpose()).transpose()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::cholesky;
    use crate::linalg::gemm::{matmul, matmul_tn};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_lower(n: usize, rng: &mut Rng) -> Mat {
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                l[(i, j)] = rng.normal();
            }
            l[(i, i)] = 1.0 + rng.f64(); // well-conditioned diagonal
        }
        l
    }

    #[test]
    fn forward_solve_inverts() {
        prop::check("solve-lower", 25, |g| {
            let n = g.size(1, 20);
            let m = g.size(1, 8);
            let mut rng = Rng::new(g.seed);
            let l = random_lower(n, &mut rng);
            let b = Mat::randn(n, m, &mut rng);
            let x = solve_lower(&l, &b);
            assert!(matmul(&l, &x).rel_diff(&b) < 1e-10);
        });
    }

    #[test]
    fn transpose_solve_inverts() {
        prop::check("solve-lower-t", 25, |g| {
            let n = g.size(1, 20);
            let m = g.size(1, 8);
            let mut rng = Rng::new(g.seed);
            let l = random_lower(n, &mut rng);
            let b = Mat::randn(n, m, &mut rng);
            let x = solve_lower_transpose(&l, &b);
            assert!(matmul(&l.transpose(), &x).rel_diff(&b) < 1e-10);
        });
    }

    #[test]
    fn upper_solve_inverts() {
        prop::check("solve-upper", 25, |g| {
            let n = g.size(1, 20);
            let m = g.size(1, 8);
            let mut rng = Rng::new(g.seed);
            let u = random_lower(n, &mut rng).transpose();
            let b = Mat::randn(n, m, &mut rng);
            let x = solve_upper(&u, &b);
            assert!(matmul(&u, &x).rel_diff(&b) < 1e-10);
        });
    }

    #[test]
    fn chol_solve_solves_spd() {
        prop::check("solve-chol", 20, |g| {
            let n = g.size(1, 16);
            let mut rng = Rng::new(g.seed);
            let x = Mat::randn(n + 4, n, &mut rng);
            let mut a = matmul_tn(&x, &x);
            a.add_diag(0.1);
            let l = cholesky(&a).unwrap();
            let b = Mat::randn(n, 3, &mut rng);
            let sol = solve_chol(&l, &b);
            assert!(matmul(&a, &sol).rel_diff(&b) < 1e-8);
        });
    }

    #[test]
    fn right_solve_matches_inverse() {
        let mut rng = Rng::new(21);
        let l = random_lower(6, &mut rng);
        let x = Mat::randn(4, 6, &mut rng);
        let y = right_solve_lower(&x, &l);
        // y * l == x
        assert!(matmul(&y, &l).rel_diff(&x) < 1e-10);
    }

    #[test]
    fn identity_solves_are_noops() {
        let b = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert!(solve_lower(&Mat::eye(2), &b).rel_diff(&b) < 1e-15);
        assert!(solve_upper(&Mat::eye(2), &b).rel_diff(&b) < 1e-15);
    }

    #[test]
    #[should_panic]
    fn singular_diagonal_panics() {
        let mut l = Mat::eye(3);
        l[(1, 1)] = 0.0;
        solve_lower(&l, &Mat::eye(3));
    }

    #[test]
    fn whitening_identity_paper_line21() {
        // The exact operation in Algorithm 1: L⁻ᵀ F L⁻¹ must equal
        // (inv(La))ᵀ F inv(Lb) computed explicitly.
        let mut rng = Rng::new(33);
        let la = random_lower(5, &mut rng);
        let lb = random_lower(5, &mut rng);
        let f = Mat::randn(5, 5, &mut rng);
        let got = right_solve_lower(&solve_lower_transpose(&la, &f), &lb);
        // Explicit inverses via solves against I.
        let la_inv = solve_lower(&la, &Mat::eye(5));
        let lb_inv = solve_lower(&lb, &Mat::eye(5));
        let want = matmul(&matmul(&la_inv.transpose(), &f), &lb_inv);
        assert!(got.rel_diff(&want) < 1e-10);
    }
}
