//! Symmetric Jacobi eigensolver — used by the exact CCA oracle (whitening
//! by C^{-1/2}) and by spectrum diagnostics.

use super::mat::Mat;

/// Eigendecomposition of a symmetric matrix: A = V·diag(w)·Vᵀ with
/// eigenvalues descending and V orthonormal columns.
pub fn sym_eig(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols, "sym_eig needs square input");
    let n = a.rows;
    let mut m = a.clone();
    // Symmetrize defensively (inputs are Gram matrices up to roundoff).
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
    let mut v = Mat::eye(n);
    let eps = 1e-14;
    for _sweep in 0..100 {
        // Largest off-diagonal magnitude for convergence test.
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off = off.max(m[(i, j)].abs());
            }
        }
        let scale = m.frob_norm().max(1e-300);
        if off <= eps * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= eps * scale {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // Update rows/cols p and q of M (symmetric rotation).
                for i in 0..n {
                    let mip = m[(i, p)];
                    let miq = m[(i, q)];
                    m[(i, p)] = c * mip - s * miq;
                    m[(i, q)] = s * mip + c * miq;
                }
                for i in 0..n {
                    let mpi = m[(p, i)];
                    let mqi = m[(q, i)];
                    m[(p, i)] = c * mpi - s * mqi;
                    m[(q, i)] = s * mpi + c * mqi;
                }
                for i in 0..n {
                    let vip = v[(i, p)];
                    let viq = v[(i, q)];
                    v[(i, p)] = c * vip - s * viq;
                    v[(i, q)] = s * vip + c * viq;
                }
            }
        }
    }
    let mut w: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    // Sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| w[b].partial_cmp(&w[a]).unwrap());
    let mut v_s = Mat::zeros(n, n);
    let mut w_s = vec![0.0; n];
    for (newj, &oldj) in order.iter().enumerate() {
        w_s[newj] = w[oldj];
        for i in 0..n {
            v_s[(i, newj)] = v[(i, oldj)];
        }
    }
    w = w_s;
    (w, v_s)
}

/// Inverse square root of an SPD matrix: A^{-1/2} = V·diag(w^{-1/2})·Vᵀ.
/// Eigenvalues below `floor` are clamped (pseudo-inverse behaviour) — the
/// exact CCA oracle uses this to whiten potentially ill-conditioned Grams.
pub fn inv_sqrt_spd(a: &Mat, floor: f64) -> Mat {
    let (w, v) = sym_eig(a);
    let n = a.rows;
    let mut out = Mat::zeros(n, n);
    // out = Σ_j w_j^{-1/2} v_j v_jᵀ
    for j in 0..n {
        let wj = w[j];
        if wj <= floor {
            continue;
        }
        let s = 1.0 / wj.sqrt();
        for i in 0..n {
            let vi = v[(i, j)] * s;
            for k in 0..n {
                out[(i, k)] += vi * v[(k, j)];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_tn};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn reconstruct(w: &[f64], v: &Mat) -> Mat {
        let n = v.rows;
        let mut vs = v.clone();
        for j in 0..n {
            for i in 0..n {
                vs[(i, j)] *= w[j];
            }
        }
        matmul(&vs, &v.transpose())
    }

    #[test]
    fn diagonal_eigs() {
        let a = Mat::from_rows(&[&[2.0, 0.0], &[0.0, 7.0]]);
        let (w, _) = sym_eig(&a);
        assert!((w[0] - 7.0).abs() < 1e-12);
        assert!((w[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → eigenvalues 3, 1.
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let (w, v) = sym_eig(&a);
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
        assert!(reconstruct(&w, &v).rel_diff(&a) < 1e-12);
    }

    #[test]
    fn reconstructs_random_symmetric() {
        prop::check("eig-reconstruct", 20, |g| {
            let n = g.size(1, 16);
            let mut rng = Rng::new(g.seed);
            let x = Mat::randn(n, n, &mut rng);
            let mut a = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    a[(i, j)] = 0.5 * (x[(i, j)] + x[(j, i)]);
                }
            }
            let (w, v) = sym_eig(&a);
            assert!(reconstruct(&w, &v).rel_diff(&a) < 1e-9);
            assert!(matmul_tn(&v, &v).rel_diff(&Mat::eye(n)) < 1e-9);
            for win in w.windows(2) {
                assert!(win[0] >= win[1] - 1e-12);
            }
        });
    }

    #[test]
    fn trace_is_eig_sum() {
        let mut rng = Rng::new(50);
        let x = Mat::randn(10, 10, &mut rng);
        let a = matmul_tn(&x, &x);
        let (w, _) = sym_eig(&a);
        assert!((a.trace() - w.iter().sum::<f64>()).abs() < 1e-9);
    }

    #[test]
    fn inv_sqrt_whitens() {
        prop::check("inv-sqrt", 15, |g| {
            let n = g.size(1, 12);
            let mut rng = Rng::new(g.seed);
            let x = Mat::randn(n + 6, n, &mut rng);
            let mut a = matmul_tn(&x, &x);
            a.add_diag(0.1);
            let w = inv_sqrt_spd(&a, 1e-12);
            // W A W = I
            let id = matmul(&matmul(&w, &a), &w);
            assert!(id.rel_diff(&Mat::eye(n)) < 1e-8, "{}", id.rel_diff(&Mat::eye(n)));
        });
    }

    #[test]
    fn inv_sqrt_clamps_null_directions() {
        // Rank-1 PSD matrix: pseudo-inverse square root must not blow up.
        let a = Mat::from_rows(&[&[1.0, 0.0], &[0.0, 0.0]]);
        let w = inv_sqrt_spd(&a, 1e-9);
        assert!((w[(0, 0)] - 1.0).abs() < 1e-12);
        assert_eq!(w[(1, 1)], 0.0);
    }

    #[test]
    fn negative_eigenvalues_handled() {
        let a = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]); // eigs ±1
        let (w, _) = sym_eig(&a);
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] + 1.0).abs() < 1e-12);
    }
}
