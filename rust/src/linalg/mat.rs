//! Row-major dense matrix.

use crate::util::rng::Rng;
use std::fmt;
use std::ops::{Index, IndexMut};

/// Row-major dense f64 matrix. Leader-side math (whitening, SVD, QR) is in
/// f64; chunk engines operate on f32 buffers and convert at the boundary.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Identity scaled by `s`.
    pub fn eye_scaled(n: usize, s: f64) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = s;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// i.i.d. N(0,1) entries — Algorithm 1 line 2/4 (`randn`).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Block the transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Select a contiguous block of columns [lo, hi).
    pub fn cols_range(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.cols);
        let mut m = Mat::zeros(self.rows, hi - lo);
        for i in 0..self.rows {
            m.row_mut(i)
                .copy_from_slice(&self.row(i)[lo..hi]);
        }
        m
    }

    pub fn scale(&mut self, s: f64) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    pub fn scaled(&self, s: f64) -> Mat {
        let mut m = self.clone();
        m.scale(s);
        m
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut m = self.clone();
        for (a, b) in m.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
        m
    }

    /// Add `s` to each diagonal entry (ridge / regularization).
    pub fn add_diag(&mut self, s: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += s;
        }
    }

    pub fn trace(&self) -> f64 {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// max |a_ij|
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, v| m.max(v.abs()))
    }

    /// ‖self − other‖_F / max(1, ‖other‖_F): relative difference.
    pub fn rel_diff(&self, other: &Mat) -> f64 {
        self.sub(other).frob_norm() / other.frob_norm().max(1.0)
    }

    /// Off-diagonal Frobenius mass — used by feasibility checks (cross
    /// covariance must be diagonal) and the Jacobi solvers.
    pub fn offdiag_norm(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.rows {
            for j in 0..self.cols {
                if i != j {
                    s += self[(i, j)] * self[(i, j)];
                }
            }
        }
        s.sqrt()
    }

    /// f32 copy of the buffer (engine boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat {
            rows,
            cols,
            data: data.iter().map(|&v| v as f64).collect(),
        }
    }

    /// Horizontal concatenation [self | other].
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut m = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            m.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            m.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        m
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_eye_indexing() {
        let mut m = Mat::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m[(0, 0)], 0.0);
        let e = Mat::eye(3);
        assert_eq!(e.trace(), 3.0);
        assert_eq!(e.offdiag_norm(), 0.0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(17, 41, &mut rng);
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
        assert_eq!(m.transpose()[(3, 5)], m[(5, 3)]);
    }

    #[test]
    fn from_rows_and_ops() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.trace(), 5.0);
        assert!((m.frob_norm() - 30f64.sqrt()).abs() < 1e-12);
        let s = m.scaled(2.0);
        assert_eq!(s[(1, 1)], 8.0);
        let d = s.sub(&m);
        assert_eq!(d, m);
    }

    #[test]
    fn add_diag_and_trace() {
        let mut m = Mat::zeros(3, 3);
        m.add_diag(2.5);
        assert_eq!(m.trace(), 7.5);
    }

    #[test]
    fn cols_range_extracts() {
        let m = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let c = m.cols_range(1, 3);
        assert_eq!(c, Mat::from_rows(&[&[2.0, 3.0], &[5.0, 6.0]]));
    }

    #[test]
    fn hcat_layout() {
        let a = Mat::from_rows(&[&[1.0], &[2.0]]);
        let b = Mat::from_rows(&[&[3.0, 4.0], &[5.0, 6.0]]);
        let c = a.hcat(&b);
        assert_eq!(c, Mat::from_rows(&[&[1.0, 3.0, 4.0], &[2.0, 5.0, 6.0]]));
    }

    #[test]
    fn f32_roundtrip() {
        let mut rng = Rng::new(2);
        let m = Mat::randn(5, 7, &mut rng);
        let r = Mat::from_f32(5, 7, &m.to_f32());
        assert!(m.rel_diff(&r) < 1e-6);
    }

    #[test]
    fn rel_diff_zero_for_identical() {
        let m = Mat::eye(4);
        assert_eq!(m.rel_diff(&m), 0.0);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Rng::new(3);
        let m = Mat::randn(200, 200, &mut rng);
        let mean: f64 = m.data.iter().sum::<f64>() / m.data.len() as f64;
        let var: f64 =
            m.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / m.data.len() as f64;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.03);
    }
}
