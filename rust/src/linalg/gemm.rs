//! Blocked GEMM (f64 for leader math, f32 for the native chunk engine).
//!
//! Layout is row-major everywhere. Three variants cover every product the
//! system needs without materializing transposes:
//!   * `matmul`    — C = A·B
//!   * `matmul_tn` — C = Aᵀ·B   (the data-pass product `Aᵀ(BQ)`)
//!   * `matmul_nt` — C = A·Bᵀ
//!
//! The f32 kernels (`sgemm_*`) are the performance-critical native path;
//! they use register-tiled micro-kernels with `k`-major inner loops so the
//! compiler can auto-vectorize. §Perf in EXPERIMENTS.md records the blocking
//! iteration history.

use super::mat::Mat;

/// C = A·B (f64).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    dgemm_nn(
        a.rows, a.cols, b.cols, &a.data, &b.data, &mut c.data,
    );
    c
}

/// C = Aᵀ·B (f64). A is (m×r), B is (m×c) → C is (r×c).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn shape mismatch");
    let mut c = Mat::zeros(a.cols, b.cols);
    dgemm_tn(a.rows, a.cols, b.cols, &a.data, &b.data, &mut c.data);
    c
}

/// C = A·Bᵀ (f64). A is (m×k), B is (n×k) → C is (m×n).
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    let mut c = Mat::zeros(a.rows, b.rows);
    dgemm_nt(a.rows, a.cols, b.rows, &a.data, &b.data, &mut c.data);
    c
}

/// f64 row-major C += A·Bᵀ. A: m×k, B: n×k, C: m×n.
///
/// 4×4 register-tiled micro-kernel with a k-major inner loop, matching its
/// siblings' blocked formulation (EXPERIMENTS.md §Perf): sixteen
/// accumulators stay in registers across the shared-k walk, so each loaded
/// A/B element feeds four FMAs instead of one — the naive dot-product
/// triple loop this replaces reloaded both operand rows per output cell.
fn dgemm_nt(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    const T: usize = 4;
    let mut i = 0;
    while i + T <= m {
        let mut j = 0;
        while j + T <= n {
            let mut acc = [[0f64; T]; T];
            for p in 0..k {
                let av: [f64; T] = std::array::from_fn(|ii| a[(i + ii) * k + p]);
                let bv: [f64; T] = std::array::from_fn(|jj| b[(j + jj) * k + p]);
                for ii in 0..T {
                    for jj in 0..T {
                        acc[ii][jj] += av[ii] * bv[jj];
                    }
                }
            }
            for ii in 0..T {
                for jj in 0..T {
                    c[(i + ii) * n + j + jj] += acc[ii][jj];
                }
            }
            j += T;
        }
        for j in j..n {
            let brow = &b[j * k..(j + 1) * k];
            for ii in 0..T {
                let arow = &a[(i + ii) * k..(i + ii + 1) * k];
                let mut s = 0.0;
                for (av, bv) in arow.iter().zip(brow) {
                    s += av * bv;
                }
                c[(i + ii) * n + j] += s;
            }
        }
        i += T;
    }
    for i in i..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut s = 0.0;
            for (av, bv) in arow.iter().zip(brow) {
                s += av * bv;
            }
            c[i * n + j] += s;
        }
    }
}

/// f64 row-major C += A·B with k-major inner loop (auto-vectorizes).
fn dgemm_nn(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &aip) in arow.iter().enumerate() {
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aip * bv;
            }
        }
    }
}

/// f64 row-major C += Aᵀ·B. A is m×r, B is m×n, C is r×n.
fn dgemm_tn(m: usize, r: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    for p in 0..m {
        let arow = &a[p * r..(p + 1) * r];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &api) in arow.iter().enumerate() {
            if api == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += api * bv;
            }
        }
    }
}

// ------------------------------------------------------------------
// f32 kernels: the native chunk engine hot path.
// ------------------------------------------------------------------

/// f32 row-major C += A·B. A: m×k, B: k×n, C: m×n.
///
/// Row-blocked (IB=8): each loaded row of B is applied to 8 rows of A at
/// once, cutting B's memory traffic 8× — the kernel is bandwidth-bound at
/// the chunk shapes (256×4096×160): 12.1 → 15.4–17.4 GFLOP/s measured on
/// the 1-core testbed (iteration log in EXPERIMENTS.md §Perf).
pub fn sgemm_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    // §Perf iteration winner (see EXPERIMENTS.md): 8-row blocking — each
    // loaded row of B is applied to 8 rows of A, cutting B's memory
    // traffic 8x (the kernel is bandwidth-bound at chunk shapes). A
    // register-tiled 4x16 micro-kernel variant measured *slower* here
    // (zero-skip branch broke vectorization), so this version is kept.
    const IB: usize = 8;
    let mut i = 0;
    while i + IB <= m {
        let crows = &mut c[i * n..(i + IB) * n];
        for p in 0..k {
            let brow = &b[p * n..(p + 1) * n];
            let avals: [f32; IB] = std::array::from_fn(|ii| a[(i + ii) * k + p]);
            if avals.iter().all(|&v| v == 0.0) {
                continue; // densified sparse chunks are mostly zeros
            }
            for (j, &bv) in brow.iter().enumerate() {
                for ii in 0..IB {
                    crows[ii * n + j] += avals[ii] * bv;
                }
            }
        }
        i += IB;
    }
    // Row remainder: plain axpy formulation.
    for i in i..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &aip) in arow.iter().enumerate() {
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aip * bv;
            }
        }
    }
}

/// f32 row-major C += Aᵀ·B. A: m×r, B: m×n, C: r×n.
pub fn sgemm_tn(m: usize, r: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * r);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), r * n);
    for p in 0..m {
        let arow = &a[p * r..(p + 1) * r];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &api) in arow.iter().enumerate() {
            if api == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += api * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn small_known_product() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = matmul(&a, &b);
        assert_eq!(c, Mat::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(13, 13, &mut rng);
        assert!(matmul(&a, &Mat::eye(13)).rel_diff(&a) < 1e-14);
        assert!(matmul(&Mat::eye(13), &a).rel_diff(&a) < 1e-14);
    }

    #[test]
    fn matches_naive_random_shapes() {
        prop::check("gemm-vs-naive", 25, |g| {
            let m = g.size(1, 24);
            let k = g.size(1, 24);
            let n = g.size(1, 24);
            let mut rng = Rng::new(g.seed ^ 1);
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.rel_diff(&naive(&a, &b)) < 1e-12);
        });
    }

    #[test]
    fn tn_matches_explicit_transpose() {
        prop::check("gemm-tn", 25, |g| {
            let m = g.size(1, 24);
            let r = g.size(1, 16);
            let n = g.size(1, 16);
            let mut rng = Rng::new(g.seed ^ 2);
            let a = Mat::randn(m, r, &mut rng);
            let b = Mat::randn(m, n, &mut rng);
            let c = matmul_tn(&a, &b);
            assert_eq!((c.rows, c.cols), (r, n));
            assert!(c.rel_diff(&naive(&a.transpose(), &b)) < 1e-12);
        });
    }

    #[test]
    fn nt_matches_explicit_transpose() {
        prop::check("gemm-nt", 25, |g| {
            let m = g.size(1, 16);
            let k = g.size(1, 24);
            let n = g.size(1, 16);
            let mut rng = Rng::new(g.seed ^ 3);
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(n, k, &mut rng);
            let c = matmul_nt(&a, &b);
            assert!(c.rel_diff(&naive(&a, &b.transpose())) < 1e-12);
        });
    }

    #[test]
    fn associativity_within_tolerance() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(9, 11, &mut rng);
        let b = Mat::randn(11, 7, &mut rng);
        let c = Mat::randn(7, 5, &mut rng);
        let l = matmul(&matmul(&a, &b), &c);
        let r = matmul(&a, &matmul(&b, &c));
        assert!(l.rel_diff(&r) < 1e-12);
    }

    #[test]
    fn sgemm_nn_matches_f64() {
        prop::check("sgemm-nn", 20, |g| {
            let m = g.size(1, 20);
            let k = g.size(1, 20);
            let n = g.size(1, 20);
            let a32 = g.normal_vec_f32(m * k, 1.0);
            let b32 = g.normal_vec_f32(k * n, 1.0);
            let mut c32 = vec![0f32; m * n];
            sgemm_nn(m, k, n, &a32, &b32, &mut c32);
            let a = Mat::from_f32(m, k, &a32);
            let b = Mat::from_f32(k, n, &b32);
            let want = matmul(&a, &b);
            let got = Mat::from_f32(m, n, &c32);
            assert!(got.rel_diff(&want) < 1e-4, "diff {}", got.rel_diff(&want));
        });
    }

    #[test]
    fn sgemm_tn_matches_f64() {
        prop::check("sgemm-tn", 20, |g| {
            let m = g.size(1, 20);
            let r = g.size(1, 20);
            let n = g.size(1, 20);
            let a32 = g.normal_vec_f32(m * r, 1.0);
            let b32 = g.normal_vec_f32(m * n, 1.0);
            let mut c32 = vec![0f32; r * n];
            sgemm_tn(m, r, n, &a32, &b32, &mut c32);
            let a = Mat::from_f32(m, r, &a32);
            let b = Mat::from_f32(m, n, &b32);
            let want = matmul_tn(&a, &b);
            let got = Mat::from_f32(r, n, &c32);
            assert!(got.rel_diff(&want) < 1e-4);
        });
    }

    #[test]
    fn sgemm_accumulates_into_c() {
        let a = [1f32, 0.0, 0.0, 1.0];
        let b = [2f32, 0.0, 0.0, 2.0];
        let mut c = [10f32, 0.0, 0.0, 10.0];
        sgemm_nn(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, [12.0, 0.0, 0.0, 12.0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        matmul(&a, &b);
    }
}
