//! Cholesky factorization — Algorithm 1 lines 19–20
//! (`La = chol(Ca + λa QaᵀQa)`).

use super::mat::Mat;
use std::fmt;

#[derive(Debug, Clone)]
pub struct NotPositiveDefinite {
    pub pivot: usize,
    pub value: f64,
}

impl fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "cholesky: matrix not positive definite at pivot {} (value {:.3e})",
            self.pivot, self.value
        )
    }
}
impl std::error::Error for NotPositiveDefinite {}

/// Lower-triangular Cholesky factor L with A = L·Lᵀ.
///
/// Input must be symmetric positive definite; asymmetry up to roundoff is
/// tolerated (the lower triangle is used). In the paper's algorithm the
/// regularizer λ·QᵀQ (λ > 0, Q full column rank) guarantees positive
/// definiteness; a failure here therefore signals a configuration error
/// (λ ≤ 0) and is surfaced as a typed error rather than a panic.
pub fn cholesky(a: &Mat) -> Result<Mat, NotPositiveDefinite> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for j in 0..n {
        // Diagonal entry.
        let mut d = a[(j, j)];
        for k in 0..j {
            d -= l[(j, k)] * l[(j, k)];
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(NotPositiveDefinite { pivot: j, value: d });
        }
        let djj = d.sqrt();
        l[(j, j)] = djj;
        // Column below the diagonal.
        for i in (j + 1)..n {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            l[(i, j)] = s / djj;
        }
    }
    Ok(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_nt, matmul_tn};
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn identity_factors_to_identity() {
        let l = cholesky(&Mat::eye(5)).unwrap();
        assert!(l.rel_diff(&Mat::eye(5)) < 1e-14);
    }

    #[test]
    fn known_3x3() {
        // A = [[4,12,-16],[12,37,-43],[-16,-43,98]] → L = [[2,0,0],[6,1,0],[-8,5,3]]
        let a = Mat::from_rows(&[
            &[4.0, 12.0, -16.0],
            &[12.0, 37.0, -43.0],
            &[-16.0, -43.0, 98.0],
        ]);
        let l = cholesky(&a).unwrap();
        let want = Mat::from_rows(&[&[2.0, 0.0, 0.0], &[6.0, 1.0, 0.0], &[-8.0, 5.0, 3.0]]);
        assert!(l.rel_diff(&want) < 1e-12);
    }

    #[test]
    fn reconstructs_random_spd() {
        prop::check("chol-reconstruct", 25, |g| {
            let n = g.size(1, 30);
            let mut rng = Rng::new(g.seed);
            let x = Mat::randn(n + 5, n, &mut rng);
            let mut a = matmul_tn(&x, &x); // XᵀX ⪰ 0, almost surely PD
            a.add_diag(1e-6);
            let l = cholesky(&a).unwrap();
            let rec = matmul_nt(&l, &l);
            assert!(rec.rel_diff(&a) < 1e-10, "rel {}", rec.rel_diff(&a));
            // L strictly lower+diagonal
            for i in 0..n {
                for j in (i + 1)..n {
                    assert_eq!(l[(i, j)], 0.0);
                }
                assert!(l[(i, i)] > 0.0);
            }
        });
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        let e = cholesky(&a).unwrap_err();
        assert_eq!(e.pivot, 1);
        assert!(e.value < 0.0);
    }

    #[test]
    fn rejects_zero_matrix() {
        assert!(cholesky(&Mat::zeros(3, 3)).is_err());
    }

    #[test]
    fn regularized_gram_always_factors() {
        // The paper's construction: C + λQᵀQ with λ>0 must be PD even when
        // C is rank-deficient.
        let mut rng = Rng::new(8);
        let q = crate::linalg::qr::orth(&Mat::randn(40, 10, &mut rng));
        let c = Mat::zeros(10, 10); // degenerate C
        let mut reg = matmul_tn(&q, &q);
        reg.scale(0.5);
        let mut a = c.clone();
        a.add_assign(&reg);
        assert!(cholesky(&a).is_ok());
        // Sanity: QᵀQ = I for orthonormal Q.
        assert!(matmul_tn(&q, &q).rel_diff(&Mat::eye(10)) < 1e-10);
        let _ = matmul(&q, &Mat::eye(10)); // exercise
    }
}
