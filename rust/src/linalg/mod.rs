//! Dense linear algebra substrate.
//!
//! The paper's Algorithm 1 needs, on the leader: `orth` (QR), `chol`,
//! triangular solves, and a dense SVD of a (k+p)×(k+p) matrix; the Horst
//! baseline additionally needs symmetric solves. No LAPACK is available to
//! the Rust runtime (jax's LAPACK custom-calls are not registered in
//! xla_extension 0.5.1), so this module implements the required kernels
//! directly:
//!
//! * [`mat::Mat`] — row-major dense matrix with f64 storage (leader math is
//!   done in f64 for stability; the data-pass engines use f32 and convert).
//! * [`gemm`] — blocked matrix multiply with transpose variants. This is
//!   also the compute core of the *native* chunk engine.
//! * [`qr`] — Householder QR; `orth()` is Algorithm 1's `orth`.
//! * [`chol`] — Cholesky with jitter-free failure reporting.
//! * [`svd`] — one-sided Jacobi SVD (full, square or tall); robust for the
//!   (k+p) ≤ few-thousand sizes the paper targets ("can be done on a single
//!   commodity machine as long as k+p ≲ 10000").
//! * [`eig`] — symmetric Jacobi eigensolver (used by the exact CCA oracle).
//! * [`solve`] — triangular / Cholesky solves.

pub mod chol;
pub mod eig;
pub mod gemm;
pub mod mat;
pub mod qr;
pub mod solve;
pub mod svd;

pub use chol::cholesky;
pub use eig::sym_eig;
pub use gemm::{matmul, matmul_nt, matmul_tn};
pub use mat::Mat;
pub use qr::{orth, qr_thin};
pub use solve::{solve_lower, solve_lower_transpose, solve_upper};
pub use svd::svd_thin;
