//! Householder QR; `orth()` implements Algorithm 1 lines 10–11.

use super::mat::Mat;

/// Thin QR of an m×n matrix with m ≥ n: returns (Q: m×n with orthonormal
/// columns, R: n×n upper triangular) such that A = Q·R.
///
/// Classic Householder triangularization followed by explicit thin-Q
/// accumulation (backward application of the reflectors to the first n
/// columns of I). Numerically stable for the tall-skinny (d × (k+p))
/// matrices the range finder produces.
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "qr_thin requires rows >= cols ({m} < {n})");
    let mut w = a.clone(); // working copy; reflectors stored below diagonal
    let mut betas = vec![0.0f64; n];

    for j in 0..n {
        // Build the Householder vector for column j, rows j..m.
        let mut norm2 = 0.0;
        for i in j..m {
            norm2 += w[(i, j)] * w[(i, j)];
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            betas[j] = 0.0;
            continue;
        }
        let alpha = if w[(j, j)] >= 0.0 { -norm } else { norm };
        let v0 = w[(j, j)] - alpha;
        // Normalize v so v[0] = 1 (stored implicitly); beta = -v0/alpha form.
        let mut vnorm2 = v0 * v0;
        for i in (j + 1)..m {
            vnorm2 += w[(i, j)] * w[(i, j)];
        }
        if vnorm2 == 0.0 {
            betas[j] = 0.0;
            w[(j, j)] = alpha;
            continue;
        }
        let beta = 2.0 / vnorm2;
        betas[j] = beta;
        // Apply H = I − beta v vᵀ to the trailing columns j..n.
        for c in (j + 1)..n {
            let mut dot = v0 * w[(j, c)];
            for i in (j + 1)..m {
                dot += w[(i, j)] * w[(i, c)];
            }
            let s = beta * dot;
            w[(j, c)] -= s * v0;
            for i in (j + 1)..m {
                let vij = w[(i, j)];
                w[(i, c)] -= s * vij;
            }
        }
        // Store: R diagonal entry, reflector tail below (v0 kept separately).
        w[(j, j)] = alpha;
        // Normalize the stored tail by v0 so that v = (1, tail/v0).
        if v0 != 0.0 {
            for i in (j + 1)..m {
                w[(i, j)] /= v0;
            }
            betas[j] = beta * v0 * v0;
        }
    }

    // Extract R (upper n×n triangle).
    let mut r = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r[(i, j)] = w[(i, j)];
        }
    }

    // Accumulate thin Q: apply reflectors H_0 … H_{n-1} in reverse to I_mn.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for j in (0..n).rev() {
        let beta = betas[j];
        if beta == 0.0 {
            continue;
        }
        for c in 0..n {
            // v = (1 at row j, w[(i,j)] for i>j)
            let mut dot = q[(j, c)];
            for i in (j + 1)..m {
                dot += w[(i, j)] * q[(i, c)];
            }
            let s = beta * dot;
            q[(j, c)] -= s;
            for i in (j + 1)..m {
                let vij = w[(i, j)];
                q[(i, c)] -= s * vij;
            }
        }
    }

    // Sign normalization: make R's diagonal non-negative (flip matching
    // Q column / R row). Gives the unique "positive" thin QR when A has
    // full column rank, and makes qr(I) = (I, I).
    for j in 0..n {
        if r[(j, j)] < 0.0 {
            for c in j..n {
                r[(j, c)] = -r[(j, c)];
            }
            for i in 0..m {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
    (q, r)
}

/// Orthonormal basis for the column space of A — Algorithm 1's `orth`.
///
/// Rank deficiency (possible when the range finder's Y has linearly
/// dependent columns, e.g. q=0 with duplicate random draws) is handled by
/// replacing null columns of Q with fresh Gram–Schmidt-completed directions:
/// a zero R diagonal marks the column, and the corresponding Q column from
/// Householder accumulation is already a valid orthonormal completion, so no
/// extra work is required — Householder Q always has exactly orthonormal
/// columns regardless of A's rank.
pub fn orth(a: &Mat) -> Mat {
    qr_thin(a).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_tn};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn assert_orthonormal(q: &Mat, tol: f64) {
        let g = matmul_tn(q, q);
        let d = g.rel_diff(&Mat::eye(q.cols));
        assert!(d < tol, "QᵀQ deviates from I by {d}");
    }

    #[test]
    fn qr_reconstructs() {
        prop::check("qr-reconstruct", 25, |g| {
            let n = g.size(1, 20);
            let m = n + g.size(0, 30);
            let mut rng = Rng::new(g.seed);
            let a = Mat::randn(m, n, &mut rng);
            let (q, r) = qr_thin(&a);
            assert_eq!((q.rows, q.cols), (m, n));
            assert_eq!((r.rows, r.cols), (n, n));
            assert_orthonormal(&q, 1e-10);
            let rec = matmul(&q, &r);
            assert!(rec.rel_diff(&a) < 1e-10, "rel {}", rec.rel_diff(&a));
            // R upper triangular
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(r[(i, j)], 0.0);
                }
            }
        });
    }

    #[test]
    fn orth_of_orthonormal_is_orthonormal() {
        let mut rng = Rng::new(11);
        let a = Mat::randn(50, 8, &mut rng);
        let q = orth(&a);
        let q2 = orth(&q);
        assert_orthonormal(&q2, 1e-12);
        // Same column space: Q2 Q2ᵀ Q = Q
        let proj = matmul(&q2, &matmul_tn(&q2, &q));
        assert!(proj.rel_diff(&q) < 1e-10);
    }

    #[test]
    fn square_identity() {
        let (q, r) = qr_thin(&Mat::eye(6));
        assert!(q.rel_diff(&Mat::eye(6)) < 1e-14);
        assert!(r.rel_diff(&Mat::eye(6)) < 1e-14);
    }

    #[test]
    fn rank_deficient_input_still_orthonormal_q() {
        // Duplicate columns → rank 1, but Q must still be orthonormal.
        let a = Mat::from_rows(&[&[1.0, 1.0], &[2.0, 2.0], &[3.0, 3.0]]);
        let q = orth(&a);
        assert_orthonormal(&q, 1e-12);
        let (qq, r) = qr_thin(&a);
        assert!(matmul(&qq, &r).rel_diff(&a) < 1e-12);
        assert!(r[(1, 1)].abs() < 1e-12, "second pivot should vanish");
    }

    #[test]
    fn zero_matrix_does_not_blow_up() {
        let a = Mat::zeros(5, 3);
        let (q, r) = qr_thin(&a);
        assert!(r.max_abs() < 1e-300);
        assert_orthonormal(&q, 1e-12); // completion directions
    }

    #[test]
    fn preserves_column_space() {
        prop::check("orth-colspace", 15, |g| {
            let n = g.size(1, 10);
            let m = n + g.size(2, 20);
            let mut rng = Rng::new(g.seed);
            let a = Mat::randn(m, n, &mut rng);
            let q = orth(&a);
            // A must be exactly representable in the Q basis: Q Qᵀ A = A.
            let rec = matmul(&q, &matmul_tn(&q, &a));
            assert!(rec.rel_diff(&a) < 1e-9);
        });
    }

    #[test]
    fn sign_stability_large_entries() {
        // Column whose head is negative (exercises the alpha sign choice).
        let a = Mat::from_rows(&[&[-5.0, 1.0], &[1.0, 2.0], &[0.5, -3.0]]);
        let (q, r) = qr_thin(&a);
        assert!(matmul(&q, &r).rel_diff(&a) < 1e-12);
        assert_orthonormal(&q, 1e-12);
    }
}
