//! One-sided Jacobi SVD — Algorithm 1 line 22 (`svd(F, k)`).
//!
//! One-sided Jacobi orthogonalizes the columns of the working matrix by
//! plane rotations; it is simple, numerically robust, and more than fast
//! enough for the (k+p)×(k+p) matrices of the final optimization (the paper
//! notes these fit on "a single commodity machine as long as k+p ≲ 10000").

use super::mat::Mat;

/// Thin SVD of an m×n matrix with m ≥ n:
/// A = U·diag(σ)·Vᵀ with U m×n, σ descending, V n×n.
pub fn svd_thin(a: &Mat) -> (Mat, Vec<f64>, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "svd_thin requires rows >= cols");
    let mut u = a.clone(); // columns rotated in place
    let mut v = Mat::eye(n);

    let eps = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram entries for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation that zeroes the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    u[(i, p)] = c * up - s * uq;
                    u[(i, q)] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < eps {
            break;
        }
    }

    // Column norms are the singular values; normalize U.
    let mut sigma: Vec<f64> = (0..n)
        .map(|j| {
            let mut s = 0.0;
            for i in 0..m {
                s += u[(i, j)] * u[(i, j)];
            }
            s.sqrt()
        })
        .collect();
    for j in 0..n {
        if sigma[j] > 1e-300 {
            for i in 0..m {
                u[(i, j)] /= sigma[j];
            }
        }
    }

    // Sort descending by sigma (stable index sort, then permute columns).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| sigma[b].partial_cmp(&sigma[a]).unwrap());
    let mut u_s = Mat::zeros(m, n);
    let mut v_s = Mat::zeros(n, n);
    let mut sig_s = vec![0.0; n];
    for (newj, &oldj) in order.iter().enumerate() {
        sig_s[newj] = sigma[oldj];
        for i in 0..m {
            u_s[(i, newj)] = u[(i, oldj)];
        }
        for i in 0..n {
            v_s[(i, newj)] = v[(i, oldj)];
        }
    }
    sigma = sig_s;
    (u_s, sigma, v_s)
}

/// Rank-k truncation helper: returns (U_k, σ_k, V_k).
pub fn svd_truncated(a: &Mat, k: usize) -> (Mat, Vec<f64>, Mat) {
    let (u, s, v) = svd_thin(a);
    let k = k.min(s.len());
    (u.cols_range(0, k), s[..k].to_vec(), v.cols_range(0, k))
}

/// Spectral norm estimate via the largest singular value.
pub fn spectral_norm(a: &Mat) -> f64 {
    // For tall matrices compute on the Gram matrix's square root via svd of A
    // directly (cheap at our sizes).
    if a.rows >= a.cols {
        svd_thin(a).1.first().copied().unwrap_or(0.0)
    } else {
        svd_thin(&a.transpose()).1.first().copied().unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_tn};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn reconstruct(u: &Mat, s: &[f64], v: &Mat) -> Mat {
        let mut us = u.clone();
        for j in 0..s.len() {
            for i in 0..us.rows {
                us[(i, j)] *= s[j];
            }
        }
        matmul(&us, &v.transpose())
    }

    fn assert_orthonormal_cols(q: &Mat, tol: f64) {
        let g = matmul_tn(q, q);
        assert!(
            g.rel_diff(&Mat::eye(q.cols)) < tol,
            "orthonormality violated: {}",
            g.rel_diff(&Mat::eye(q.cols))
        );
    }

    #[test]
    fn diagonal_matrix_svd() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 5.0]]);
        let (u, s, v) = svd_thin(&a);
        assert!((s[0] - 5.0).abs() < 1e-12);
        assert!((s[1] - 3.0).abs() < 1e-12);
        assert!(reconstruct(&u, &s, &v).rel_diff(&a) < 1e-12);
    }

    #[test]
    fn reconstructs_random() {
        prop::check("svd-reconstruct", 20, |g| {
            let n = g.size(1, 16);
            let m = n + g.size(0, 16);
            let mut rng = Rng::new(g.seed);
            let a = Mat::randn(m, n, &mut rng);
            let (u, s, v) = svd_thin(&a);
            assert!(reconstruct(&u, &s, &v).rel_diff(&a) < 1e-9);
            assert_orthonormal_cols(&u, 1e-9);
            assert_orthonormal_cols(&v, 1e-9);
            // descending, non-negative
            for w in s.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
            assert!(s.iter().all(|&x| x >= 0.0));
        });
    }

    #[test]
    fn known_rank_one() {
        // A = 2 * outer(e1, [3/5, 4/5]) → sigma = 2, rank 1.
        let a = Mat::from_rows(&[&[1.2, 1.6], &[0.0, 0.0], &[0.0, 0.0]]);
        let (_, s, _) = svd_thin(&a);
        assert!((s[0] - 2.0).abs() < 1e-12, "{s:?}");
        assert!(s[1].abs() < 1e-12);
    }

    #[test]
    fn singular_values_match_eig_of_gram() {
        let mut rng = Rng::new(42);
        let a = Mat::randn(20, 8, &mut rng);
        let (_, s, _) = svd_thin(&a);
        let gram = matmul_tn(&a, &a);
        // trace(AᵀA) = Σ σ²
        let tr = gram.trace();
        let ssum: f64 = s.iter().map(|x| x * x).sum();
        assert!((tr - ssum).abs() / tr < 1e-10);
    }

    #[test]
    fn truncated_svd_shapes() {
        let mut rng = Rng::new(43);
        let a = Mat::randn(12, 9, &mut rng);
        let (u, s, v) = svd_truncated(&a, 4);
        assert_eq!((u.rows, u.cols), (12, 4));
        assert_eq!(s.len(), 4);
        assert_eq!((v.rows, v.cols), (9, 4));
    }

    #[test]
    fn truncation_is_best_approx() {
        // Eckart–Young sanity: rank-k truncation error equals σ_{k+1} in
        // spectral norm (checked loosely in Frobenius).
        let mut rng = Rng::new(44);
        let a = Mat::randn(15, 10, &mut rng);
        let (u, s, v) = svd_thin(&a);
        let k = 4;
        let rec = reconstruct(
            &u.cols_range(0, k),
            &s[..k],
            &v.cols_range(0, k),
        );
        let err = a.sub(&rec).frob_norm();
        let want: f64 = s[k..].iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((err - want).abs() / want < 1e-9);
    }

    #[test]
    fn zero_matrix() {
        let (u, s, _v) = svd_thin(&Mat::zeros(6, 3));
        assert!(s.iter().all(|&x| x == 0.0));
        assert_eq!(u.rows, 6);
    }

    #[test]
    fn spectral_norm_of_orthonormal_is_one() {
        let mut rng = Rng::new(45);
        let q = crate::linalg::qr::orth(&Mat::randn(30, 6, &mut rng));
        assert!((spectral_norm(&q) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn wide_matrix_spectral_norm() {
        let a = Mat::from_rows(&[&[0.0, 2.0, 0.0]]);
        assert!((spectral_norm(&a) - 2.0).abs() < 1e-12);
    }
}
