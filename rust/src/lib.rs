//! # rcca — RandomizedCCA, reproduced as a deployable system
//!
//! A Rust + JAX + Pallas implementation of *"A Randomized Algorithm for
//! CCA"* (Mineiro & Karampatziakis, 2014): a two-pass randomized solver for
//! large-scale canonical correlation analysis, plus the Horst-iteration
//! baseline, a leader/worker data-pass coordinator, and an XLA/PJRT compute
//! runtime whose kernels are authored in JAX/Pallas and AOT-compiled to HLO.
//!
//! Layering (Python never runs on the request path):
//! * **L3** (`coordinator`, `main.rs`) — pass orchestration over sharded
//!   two-view datasets; scheduling, tree reduction, backpressure, metrics.
//! * **L2** (`python/compile/model.py`) — chunk-level JAX functions
//!   (`power_chunk`, `final_chunk`, …) lowered once to `artifacts/*.hlo.txt`.
//! * **L1** (`python/compile/kernels/`) — Pallas matmul/gram kernels called
//!   by L2, verified against pure-jnp oracles.
//! * `runtime` — loads the artifacts via the PJRT C API (`xla` crate,
//!   behind the `pjrt` cargo feature) or falls back to the native Rust
//!   engine (`linalg` + `sparse`).
//! * [`api`] — the session layer every consumer goes through:
//!   `Cca::builder() → fit → FittedModel` with transform, persistence, and
//!   warm-start; `Engine::{in_memory, sharded, from_spec}` unifies engine
//!   construction.
//! * [`serve`] — the fit→serve half of the lifecycle: a std-only HTTP/1.1
//!   model server (`repro serve`) with request batching, atomic model
//!   hot-swap, and a metrics surface; `repro transform` is its offline
//!   twin over the same wire schema.
//! * [`cluster`] — the distributed half of L3: driver/worker fitting over
//!   TCP (`repro worker` + `repro fit --cluster`), one pass = one network
//!   round, with heartbeat-based failure detection and mid-pass shard
//!   redistribution; workers run the same shard-task code as the
//!   in-process coordinator, so results are bit-reproducible.
//! * [`chaos`] — crate-wide deterministic fault injection: declarative
//!   plans for the fit side (worker kills, torn checkpoints; `repro
//!   worker --chaos`) and the serve side (stalled reads, torn writes,
//!   batcher stalls, corrupt reloads, handler panics; `repro serve
//!   --chaos`), every fault fired at a pre-declared point with a finite
//!   budget so chaos runs stay reproducible and always recover.
//! * [`telemetry`] — the observability substrate under all of the above:
//!   structured tracing spans recorded into a per-thread flight recorder
//!   (JSONL export, `repro trace` viewer) and a unified `MetricsRegistry`
//!   that renders every subsystem's counters as both the legacy JSON
//!   shapes and Prometheus text format.
//! * [`lifecycle`] — the closed loop over all of the above: versioned
//!   snapshot manifests over shard stores, validate-then-append ingest
//!   (`repro ingest`), drift monitoring against the live model, and a
//!   warm-refit daemon (`repro daemon`) that hot-swaps refits into the
//!   serve registry and records every episode in an audit ledger.
//!
//! See DESIGN.md for the full system inventory and the per-experiment index.

pub mod api;
pub mod bench;
pub mod cca;
pub mod chaos;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod lifecycle;
pub mod runtime;
pub mod linalg;
pub mod serve;
pub mod sparse;
pub mod telemetry;
pub mod util;
