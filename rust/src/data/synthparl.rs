//! SynthParl — synthetic aligned parallel corpus (Europarl substitute).
//!
//! Generative model (per sentence pair):
//!   1. draw a topic `z` from a power-law prior  p(z) ∝ (z+1)^{-decay};
//!   2. for each language independently, draw a length, then each token is
//!      * with prob `noise`: a background word from a global Zipf
//!        distribution (shared "stopword" mass — creates the dominant top
//!        singular directions plus broadband noise, like real text), or
//!      * otherwise: a topic word from topic `z`'s language-specific Zipf
//!        distribution over that topic's private vocabulary block.
//!
//! Because the topic is shared across the two languages while all word
//! draws are conditionally independent, the population cross-covariance
//! between views factors through the topics and its spectrum inherits the
//! power-law topic prior — exactly the structure the paper's Figure 1
//! measures on Europarl. The number of usable canonical directions is
//! governed by `topics`, so experiments with k = 60 (the paper's choice)
//! plant `topics` ≥ 60 correlated directions.

use super::hashing::Hasher;
use crate::sparse::{Csr, CsrBuilder};
use crate::util::rng::{Rng, Zipf};

#[derive(Debug, Clone)]
pub struct SynthParlConfig {
    /// Number of sentence pairs.
    pub n: usize,
    /// Hashed feature dimension per view (paper: 2^19; scaled default 2^12).
    pub dims: usize,
    /// Latent topics (≥ k for a meaningful k-dim CCA).
    pub topics: usize,
    /// Power-law exponent of the topic prior (spectrum decay rate).
    pub topic_decay: f64,
    /// Per-topic vocabulary block size (per language).
    pub words_per_topic: usize,
    /// Zipf exponent within a topic's vocabulary.
    pub word_zipf: f64,
    /// Background ("stopword") vocabulary size.
    pub background_words: usize,
    /// Probability a token is background noise rather than topical.
    pub noise: f64,
    /// Mean sentence length (tokens), per language.
    pub mean_len: f64,
    /// L2-normalize hashed rows.
    pub normalize: bool,
    pub seed: u64,
    /// Batch index for streaming scenarios: perturbs only the row-sampling
    /// RNG (never the feature hashers), so batch 1, 2, … draw fresh
    /// sentences from the *same* corpus distribution and hash into the
    /// same feature space as batch 0.
    pub batch: u64,
    /// Concept-drift intensity in [0, 1]: the probability that a language-B
    /// token's topic is resampled independently of the shared topic. 0.0
    /// reproduces the undrifted corpus bit-for-bit; higher values decay the
    /// planted cross-view correlation toward chance.
    pub drift: f64,
}

impl Default for SynthParlConfig {
    fn default() -> Self {
        SynthParlConfig {
            n: 10_000,
            dims: 1 << 12,
            topics: 96,
            topic_decay: 1.05,
            words_per_topic: 40,
            word_zipf: 1.2,
            background_words: 500,
            noise: 0.3,
            mean_len: 16.0,
            normalize: true,
            seed: 0x5eed,
            batch: 0,
            drift: 0.0,
        }
    }
}

/// The generated two-view dataset.
#[derive(Debug, Clone)]
pub struct SynthParl {
    pub a: Csr,
    pub b: Csr,
    pub config: SynthParlConfig,
    /// Topic assignment per row (kept for diagnostics/tests).
    pub topic_of_row: Vec<u32>,
}

impl SynthParl {
    /// Generate the corpus. Deterministic in `config.seed`.
    pub fn generate(config: SynthParlConfig) -> SynthParl {
        assert!(config.topics > 0 && config.words_per_topic > 0);
        // `batch` folds into the row-sampling stream only; the hashers stay
        // keyed by `seed` alone so every batch shares one feature space.
        let mut rng = Rng::new(config.seed ^ config.batch.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        // Topic prior: power law.
        let topic_cdf = power_law_cdf(config.topics, config.topic_decay);
        // Within-topic and background word distributions share a Zipf shape.
        let word_zipf = Zipf::new(config.words_per_topic, config.word_zipf);
        let bg_zipf = Zipf::new(config.background_words, 1.07);

        // Token id layout (per language, disjoint by construction):
        //   background: [0, background_words)
        //   topic t:    [background_words + t·wpt, … + wpt)
        // Language B ids are offset by a large constant so the two views'
        // hash functions see disjoint token universes even before salting.
        const LANG_B_OFFSET: u64 = 1 << 40;

        let hasher_a = Hasher::new(config.dims, 0xa11ce ^ config.seed);
        let hasher_b = Hasher::new(config.dims, 0xb0b ^ config.seed.rotate_left(21));

        let mut ba = CsrBuilder::new(config.dims);
        let mut bb = CsrBuilder::new(config.dims);
        let mut scratch = Vec::new();
        let mut tokens: Vec<u64> = Vec::new();
        let mut topic_of_row = Vec::with_capacity(config.n);

        for _ in 0..config.n {
            let z = sample_cdf(&topic_cdf, &mut rng) as u64;
            topic_of_row.push(z as u32);
            for lang in 0..2u8 {
                let offset = if lang == 0 { 0 } else { LANG_B_OFFSET };
                let len = rng.doc_len(config.mean_len);
                tokens.clear();
                for _ in 0..len {
                    let tok = if rng.f64() < config.noise {
                        offset + bg_zipf.sample(&mut rng) as u64
                    } else {
                        // Drift: resample language B's topic with prob
                        // `drift`, decoupling the views. The guard on
                        // `drift > 0.0` keeps legacy streams bit-identical
                        // (no extra RNG draw when the knob is off).
                        let zt = if config.drift > 0.0 && lang == 1 && rng.f64() < config.drift {
                            sample_cdf(&topic_cdf, &mut rng) as u64
                        } else {
                            z
                        };
                        offset
                            + config.background_words as u64
                            + zt * config.words_per_topic as u64
                            + word_zipf.sample(&mut rng) as u64
                    };
                    tokens.push(tok);
                }
                if lang == 0 {
                    hasher_a.hash_row(&tokens, config.normalize, &mut ba, &mut scratch);
                } else {
                    hasher_b.hash_row(&tokens, config.normalize, &mut bb, &mut scratch);
                }
            }
        }
        let a = ba.finish();
        let b = bb.finish();
        debug_assert!(a.validate().is_ok() && b.validate().is_ok());
        SynthParl {
            a,
            b,
            config,
            topic_of_row,
        }
    }
}

fn power_law_cdf(n: usize, decay: f64) -> Vec<f64> {
    let mut cdf = Vec::with_capacity(n);
    let mut acc = 0.0;
    for t in 0..n {
        acc += 1.0 / ((t + 1) as f64).powf(decay);
        cdf.push(acc);
    }
    for c in cdf.iter_mut() {
        *c /= acc;
    }
    cdf
}

fn sample_cdf(cdf: &[f64], rng: &mut Rng) -> usize {
    let u = rng.f64();
    match cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
        Ok(i) => i,
        Err(i) => i.min(cdf.len() - 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_tn;
    use crate::linalg::Mat;

    fn small_config() -> SynthParlConfig {
        SynthParlConfig {
            n: 2_000,
            dims: 512,
            topics: 16,
            words_per_topic: 20,
            background_words: 100,
            mean_len: 12.0,
            seed: 99,
            ..Default::default()
        }
    }

    #[test]
    fn shapes_and_validity() {
        let d = SynthParl::generate(small_config());
        assert_eq!(d.a.rows, 2_000);
        assert_eq!(d.b.rows, 2_000);
        assert_eq!(d.a.cols, 512);
        d.a.validate().unwrap();
        d.b.validate().unwrap();
        assert_eq!(d.topic_of_row.len(), 2_000);
    }

    #[test]
    fn deterministic_in_seed() {
        let d1 = SynthParl::generate(small_config());
        let d2 = SynthParl::generate(small_config());
        assert_eq!(d1.a, d2.a);
        assert_eq!(d1.b, d2.b);
        let mut cfg = small_config();
        cfg.seed = 100;
        let d3 = SynthParl::generate(cfg);
        assert_ne!(d1.a, d3.a);
    }

    #[test]
    fn batches_differ_but_share_the_feature_space() {
        // drift=0.0, batch=0 must stay bit-identical to the pre-knob
        // generator (no extra RNG draws) — covered by deterministic_in_seed.
        let d0 = SynthParl::generate(small_config());
        let d1 = SynthParl::generate(SynthParlConfig {
            batch: 1,
            ..small_config()
        });
        assert_ne!(d0.a, d1.a, "a new batch draws new rows");
        // Same hashers → same dims, and exact CCA on batch 1 still finds
        // the planted topics (same distribution, fresh sample).
        assert_eq!((d1.a.cols, d1.b.cols), (d0.a.cols, d0.b.cols));
        // Determinism in (seed, batch).
        let d1b = SynthParl::generate(SynthParlConfig {
            batch: 1,
            ..small_config()
        });
        assert_eq!(d1.a, d1b.a);
    }

    #[test]
    fn drift_decays_the_planted_correlation() {
        let mut cfg = small_config();
        cfg.dims = 128;
        cfg.n = 1500;
        let clean = SynthParl::generate(cfg.clone());
        cfg.drift = 0.8;
        let drifted = SynthParl::generate(cfg);
        let corr = |d: &SynthParl| {
            let m = crate::cca::exact::exact_cca(&d.a.to_dense(), &d.b.to_dense(), 4, 0.1, 0.1);
            m.sigma.iter().sum::<f64>()
        };
        let (sc, sd) = (corr(&clean), corr(&drifted));
        assert!(
            sc > sd + 0.2,
            "drift should decay correlation: clean {sc} vs drifted {sd}"
        );
    }

    #[test]
    fn rows_are_normalized() {
        let d = SynthParl::generate(small_config());
        for i in 0..50 {
            let (_, vals) = d.a.row(i);
            if vals.is_empty() {
                continue;
            }
            let norm: f32 = vals.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-4, "row {i} norm {norm}");
        }
    }

    #[test]
    fn topic_prior_is_decreasing() {
        let d = SynthParl::generate(SynthParlConfig {
            n: 20_000,
            ..small_config()
        });
        let mut counts = vec![0usize; 16];
        for &t in &d.topic_of_row {
            counts[t as usize] += 1;
        }
        assert!(counts[0] > counts[8]);
        assert!(counts[0] > counts[15]);
        assert!(counts.iter().all(|&c| c > 0), "all topics used");
    }

    #[test]
    fn planted_correlation_is_cca_detectable() {
        // Raw cross-view dot products are meaningless under independent
        // per-view hash functions — the planted signal lives in the joint
        // covariance. Exact CCA on aligned data must find much stronger
        // canonical correlations than on misaligned (row-shuffled B) data.
        let mut cfg = small_config();
        cfg.dims = 128;
        cfg.n = 1500;
        let d = SynthParl::generate(cfg);
        let da = d.a.to_dense();
        let db = d.b.to_dense();
        let aligned = crate::cca::exact::exact_cca(&da, &db, 4, 0.1, 0.1);

        // Break the alignment: reverse B's rows (topic pairing destroyed
        // except by chance).
        let mut rev_rows: Vec<&[f64]> = Vec::with_capacity(db.rows);
        for i in (0..db.rows).rev() {
            rev_rows.push(db.row(i));
        }
        let db_rev = Mat::from_rows(&rev_rows);
        let shuffled = crate::cca::exact::exact_cca(&da, &db_rev, 4, 0.1, 0.1);

        let sa: f64 = aligned.sigma.iter().sum();
        let ss: f64 = shuffled.sigma.iter().sum();
        assert!(
            sa > ss + 0.2,
            "aligned {sa} should exceed shuffled {ss} decisively"
        );
    }

    #[test]
    fn spectrum_has_decay() {
        // The singular values of (1/n)AᵀB should decay strongly (Fig 1
        // qualitative shape). Use a small dense check.
        let d = SynthParl::generate(small_config());
        let m = matmul_tn(&d.a.to_dense(), &d.b.to_dense()).scaled(1.0 / 2000.0);
        let (_, s, _) = crate::linalg::svd::svd_thin(&m);
        // Top value should dominate the 100th by a large factor.
        assert!(
            s[0] > 5.0 * s[99],
            "insufficient decay: s0={} s99={}",
            s[0],
            s[99]
        );
        // And there should be a meaningful correlated band (topics).
        assert!(s[10] > 0.01 * s[0]);
    }
}
