//! Data layer: synthetic parallel corpus, feature hashing, shard storage.
//!
//! The paper's workload is Europarl (n = 1.24M aligned English/Greek
//! sentences) turned into two hashed bag-of-words views with 2^19 slots.
//! Europarl is not available in this environment, so [`synthparl`]
//! implements the documented substitution (DESIGN.md §3): a latent-topic
//! parallel-corpus generator whose cross-covariance spectrum has the same
//! power-law decay the paper's Figure 1 shows, followed by the identical
//! inner-product-preserving hashing trick ([16] in the paper).

pub mod hashing;
pub mod shards;
pub mod split;
pub mod stream;
pub mod synthparl;

pub use hashing::Hasher;
pub use shards::{ShardScratch, ShardStore, ShardWriter, TwoViewChunk, TwoViewChunkRef};
pub use stream::{BufferPool, PooledBytes, ShardStreamer, StreamConfig, StreamCounters};
pub use synthparl::{SynthParl, SynthParlConfig};
