//! Feature hashing (Weinberger et al., ICML 2009 — reference [16]).
//!
//! Each token is mapped to a slot `h(x) mod d` with a sign `ξ(x) ∈ {±1}`
//! drawn from an independent hash bit; the signed sum preserves inner
//! products in expectation. This is exactly the paper's preprocessing
//! ("bag of words composed with inner-product preserving hashing").

use crate::sparse::CsrBuilder;

/// A stateless 64-bit mix hash (splitmix-style finalizer over a keyed
/// input). Distinct `salt`s give independent hash functions per view.
#[derive(Debug, Clone, Copy)]
pub struct Hasher {
    pub dims: usize,
    salt: u64,
}

impl Hasher {
    pub fn new(dims: usize, salt: u64) -> Hasher {
        assert!(dims > 0);
        Hasher { dims, salt }
    }

    #[inline]
    fn mix(&self, x: u64) -> u64 {
        let mut z = x ^ self.salt.wrapping_mul(0x9e3779b97f4a7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Slot index for a token id.
    #[inline]
    pub fn slot(&self, token: u64) -> u32 {
        (self.mix(token) % self.dims as u64) as u32
    }

    /// ±1 sign for a token id (independent bit from the same mix).
    #[inline]
    pub fn sign(&self, token: u64) -> f32 {
        // Use a high bit not consumed by the modulo.
        if self.mix(token ^ 0xabcdef1234567890) >> 63 == 1 {
            -1.0
        } else {
            1.0
        }
    }

    /// Hash a string token (e.g. real corpus words) to an id first.
    pub fn slot_str(&self, token: &str) -> u32 {
        self.slot(str_id(token))
    }

    pub fn sign_str(&self, token: &str) -> f32 {
        self.sign(str_id(token))
    }

    /// Hash a bag of token ids into a signed-count CSR row (appended to the
    /// builder). `l2_normalize` divides by the row's L2 norm so every
    /// document has unit energy (keeps tr(AᵀA) ≈ n regardless of length).
    pub fn hash_row(
        &self,
        tokens: &[u64],
        l2_normalize: bool,
        builder: &mut CsrBuilder,
        scratch: &mut Vec<(u32, f32)>,
    ) {
        scratch.clear();
        for &t in tokens {
            scratch.push((self.slot(t), self.sign(t)));
        }
        if l2_normalize && !scratch.is_empty() {
            // Builder will merge duplicates; compute the post-merge norm by
            // merging locally first.
            scratch.sort_by_key(|&(j, _)| j);
            let mut merged: Vec<(u32, f32)> = Vec::with_capacity(scratch.len());
            for &(j, v) in scratch.iter() {
                match merged.last_mut() {
                    Some((pj, pv)) if *pj == j => *pv += v,
                    _ => merged.push((j, v)),
                }
            }
            let norm: f32 = merged.iter().map(|(_, v)| v * v).sum::<f32>().sqrt();
            if norm > 0.0 {
                for (_, v) in merged.iter_mut() {
                    *v /= norm;
                }
            }
            *scratch = merged;
        }
        builder.push_row(scratch);
    }
}

/// FNV-1a over a string for stable string → id mapping.
pub fn str_id(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn deterministic() {
        let h = Hasher::new(128, 7);
        assert_eq!(h.slot(42), h.slot(42));
        assert_eq!(h.sign(42), h.sign(42));
    }

    #[test]
    fn salt_changes_function() {
        let h1 = Hasher::new(1 << 16, 1);
        let h2 = Hasher::new(1 << 16, 2);
        let collisions = (0..1000u64).filter(|&t| h1.slot(t) == h2.slot(t)).count();
        assert!(collisions < 10, "salts not independent: {collisions}");
    }

    #[test]
    fn slots_in_range_and_spread() {
        let d = 256;
        let h = Hasher::new(d, 3);
        let mut counts = vec![0usize; d];
        for t in 0..51_200u64 {
            let s = h.slot(t) as usize;
            assert!(s < d);
            counts[s] += 1;
        }
        // Each slot expects 200; allow generous deviation.
        for (i, c) in counts.iter().enumerate() {
            assert!(*c > 100 && *c < 320, "slot {i} count {c}");
        }
    }

    #[test]
    fn signs_balanced() {
        let h = Hasher::new(1 << 10, 5);
        let pos = (0..10_000u64).filter(|&t| h.sign(t) > 0.0).count();
        assert!((4_500..5_500).contains(&pos), "{pos}");
    }

    #[test]
    fn inner_product_preserved_in_expectation() {
        // <φ(x), φ(y)> ≈ <x, y> for disjoint bags: signed hashing makes the
        // cross terms mean-zero. Empirically check relative error over
        // random bags at high dimension.
        let d = 1 << 14;
        let h = Hasher::new(d, 11);
        let mut rng = Rng::new(1);
        let mut dots = Vec::new();
        for _ in 0..30 {
            // Two bags sharing exactly 5 tokens.
            let shared: Vec<u64> = (0..5).map(|_| rng.next_u64()).collect();
            let xa: Vec<u64> = shared
                .iter()
                .cloned()
                .chain((0..20).map(|_| rng.next_u64()))
                .collect();
            let xb: Vec<u64> = shared
                .iter()
                .cloned()
                .chain((0..20).map(|_| rng.next_u64()))
                .collect();
            // φ(xa)·φ(xb) computed sparsely.
            let mut va = std::collections::HashMap::new();
            for &t in &xa {
                *va.entry(h.slot(t)).or_insert(0.0f64) += h.sign(t) as f64;
            }
            let mut dot = 0.0;
            for &t in &xb {
                if let Some(&v) = va.get(&h.slot(t)) {
                    dot += v * h.sign(t) as f64;
                }
            }
            dots.push(dot);
        }
        let mean: f64 = dots.iter().sum::<f64>() / dots.len() as f64;
        // True inner product is 5 (shared tokens), all distinct otherwise.
        assert!((mean - 5.0).abs() < 1.0, "mean dot {mean}");
    }

    #[test]
    fn hash_row_l2_normalizes() {
        let h = Hasher::new(64, 13);
        let mut b = CsrBuilder::new(64);
        let mut scratch = Vec::new();
        h.hash_row(&[1, 2, 3, 4, 5], true, &mut b, &mut scratch);
        let c = b.finish();
        let norm: f32 = c.values.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5, "norm {norm}");
    }

    #[test]
    fn hash_row_empty_ok() {
        let h = Hasher::new(64, 13);
        let mut b = CsrBuilder::new(64);
        let mut scratch = Vec::new();
        h.hash_row(&[], true, &mut b, &mut scratch);
        let c = b.finish();
        assert_eq!(c.rows, 1);
        assert_eq!(c.nnz(), 0);
    }

    #[test]
    fn str_tokens_stable() {
        let h = Hasher::new(1 << 12, 17);
        assert_eq!(h.slot_str("parliament"), h.slot_str("parliament"));
        assert_eq!(str_id("a"), str_id("a"));
        assert_ne!(str_id("a"), str_id("b"));
        let _ = h.sign_str("parliament");
    }
}
