//! Deterministic train/test splitting (paper §4: "a single random 9:1 split
//! of sentences into train and test sets").

use crate::sparse::Csr;

/// Split row indices into (train, test) by hashing the row index with the
//  seed — stable under re-generation and independent of shard layout.
pub fn split_indices(n: usize, test_fraction: f64, seed: u64) -> (Vec<usize>, Vec<usize>) {
    assert!((0.0..1.0).contains(&test_fraction));
    let threshold = (test_fraction * u64::MAX as f64) as u64;
    let mut train = Vec::new();
    let mut test = Vec::new();
    for i in 0..n {
        let mut z = (i as u64).wrapping_add(seed.rotate_left(32)) ^ 0x9e3779b97f4a7c15;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^= z >> 31;
        if z < threshold {
            test.push(i);
        } else {
            train.push(i);
        }
    }
    (train, test)
}

/// Gather a subset of rows into a new CSR.
pub fn gather_rows(c: &Csr, rows: &[usize]) -> Csr {
    let mut indptr = vec![0usize];
    let mut indices = Vec::new();
    let mut values = Vec::new();
    for &i in rows {
        let (idx, vals) = c.row(i);
        indices.extend_from_slice(idx);
        values.extend_from_slice(vals);
        indptr.push(indices.len());
    }
    let out = Csr {
        rows: rows.len(),
        cols: c.cols,
        indptr,
        indices,
        values,
    };
    debug_assert!(out.validate().is_ok());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CsrBuilder;

    #[test]
    fn partition_is_complete_and_disjoint() {
        let (train, test) = split_indices(10_000, 0.1, 42);
        assert_eq!(train.len() + test.len(), 10_000);
        let mut all: Vec<usize> = train.iter().chain(test.iter()).cloned().collect();
        all.sort();
        assert_eq!(all, (0..10_000).collect::<Vec<_>>());
    }

    #[test]
    fn fraction_approximate() {
        let (_, test) = split_indices(50_000, 0.1, 7);
        let frac = test.len() as f64 / 50_000.0;
        assert!((frac - 0.1).abs() < 0.01, "{frac}");
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let s1 = split_indices(1000, 0.2, 1);
        let s2 = split_indices(1000, 0.2, 1);
        assert_eq!(s1, s2);
        let s3 = split_indices(1000, 0.2, 2);
        assert_ne!(s1.1, s3.1);
    }

    #[test]
    fn zero_fraction_gives_all_train() {
        let (train, test) = split_indices(100, 0.0, 3);
        assert_eq!(train.len(), 100);
        assert!(test.is_empty());
    }

    #[test]
    fn gather_preserves_rows() {
        let mut b = CsrBuilder::new(8);
        for i in 0..5u32 {
            let mut p = vec![(i % 8, (i + 1) as f32)];
            b.push_row(&mut p);
        }
        let c = b.finish();
        let g = gather_rows(&c, &[4, 0, 2]);
        assert_eq!(g.rows, 3);
        assert_eq!(g.row(0).1, &[5.0]);
        assert_eq!(g.row(1).1, &[1.0]);
        assert_eq!(g.row(2).1, &[3.0]);
    }

    #[test]
    fn gather_empty_selection() {
        let mut b = CsrBuilder::new(4);
        let mut p = vec![(0u32, 1.0f32)];
        b.push_row(&mut p);
        let c = b.finish();
        let g = gather_rows(&c, &[]);
        assert_eq!(g.rows, 0);
        assert_eq!(g.nnz(), 0);
    }
}
