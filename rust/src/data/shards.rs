//! On-disk sharded two-view dataset.
//!
//! The coordinator's unit of work is a *shard*: a row-aligned slice of both
//! views stored in one binary file. Format (little-endian):
//!
//! ```text
//! magic  "RCCA"            4 bytes
//! version u32              (currently 1)
//! rows    u64
//! dims_a  u64
//! dims_b  u64
//! view A: nnz u64, indptr (rows+1)×u64, indices nnz×u32, values nnz×f32
//! view B: same layout
//! crc32   u32              over everything after the magic
//! ```
//!
//! A dataset directory holds `meta.json` (row/shard counts, dims, seed) and
//! `shard-NNNNN.bin` files. Readers validate the CRC and CSR structure, so
//! torn writes and corruption are detected rather than silently computed on.

use crate::sparse::{Csr, CsrRef};
use crate::util::json::{jnum, jstr, Json};
use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"RCCA";
const VERSION: u32 = 1;

/// A row-aligned pair of CSR chunks (one shard's content).
#[derive(Debug, Clone, PartialEq)]
pub struct TwoViewChunk {
    pub a: Csr,
    pub b: Csr,
}

impl TwoViewChunk {
    pub fn rows(&self) -> usize {
        debug_assert_eq!(self.a.rows, self.b.rows);
        self.a.rows
    }

    /// Borrowed view (the [`crate::runtime::ChunkEngine`] currency).
    pub fn view(&self) -> TwoViewChunkRef<'_> {
        TwoViewChunkRef {
            a: self.a.view(),
            b: self.b.view(),
        }
    }
}

/// Borrowed two-view chunk: a pair of row-aligned [`CsrRef`]s. This is
/// what the chunk engines consume — the cached regime views owned
/// [`TwoViewChunk`]s, the streaming regime views a pooled decode buffer,
/// and both produce bitwise-identical kernel results.
#[derive(Debug, Clone, Copy)]
pub struct TwoViewChunkRef<'a> {
    pub a: CsrRef<'a>,
    pub b: CsrRef<'a>,
}

impl<'a> From<&'a TwoViewChunk> for TwoViewChunkRef<'a> {
    fn from(c: &'a TwoViewChunk) -> TwoViewChunkRef<'a> {
        c.view()
    }
}

impl<'a> TwoViewChunkRef<'a> {
    pub fn rows(&self) -> usize {
        debug_assert_eq!(self.a.rows, self.b.rows);
        self.a.rows
    }

    /// Row-slice both views — zero-copy (see [`CsrRef::slice_rows`]).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> TwoViewChunkRef<'a> {
        TwoViewChunkRef {
            a: self.a.slice_rows(lo, hi),
            b: self.b.slice_rows(lo, hi),
        }
    }

    /// Materialize an owned chunk (copies).
    pub fn to_chunk(&self) -> TwoViewChunk {
        TwoViewChunk {
            a: self.a.to_csr(),
            b: self.b.to_csr(),
        }
    }
}

/// CRC-32 (IEEE) — small table-driven implementation.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb88320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = 0xffffffffu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xff) as usize] ^ (crc >> 8);
    }
    !crc
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn encode_view(buf: &mut Vec<u8>, c: &Csr) {
    push_u64(buf, c.nnz() as u64);
    for &p in &c.indptr {
        push_u64(buf, p as u64);
    }
    for &i in &c.indices {
        push_u32(buf, i);
    }
    for &v in &c.values {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.data.len() {
            return Err(format!("shard truncated at byte {}", self.pos));
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Serialize a shard to bytes.
pub fn encode_shard(chunk: &TwoViewChunk) -> Vec<u8> {
    assert_eq!(chunk.a.rows, chunk.b.rows, "views must be row-aligned");
    let mut body = Vec::new();
    push_u32(&mut body, VERSION);
    push_u64(&mut body, chunk.a.rows as u64);
    push_u64(&mut body, chunk.a.cols as u64);
    push_u64(&mut body, chunk.b.cols as u64);
    encode_view(&mut body, &chunk.a);
    encode_view(&mut body, &chunk.b);
    let crc = crc32(&body);
    let mut out = Vec::with_capacity(4 + body.len() + 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&body);
    push_u32(&mut out, crc);
    out
}

/// Integrity half of shard decoding: magic + CRC over the whole payload.
/// The streaming pipeline runs this on the I/O thread that just read the
/// bytes (sequential, cache-hot), so a corrupt shard is rejected before it
/// ever reaches a compute thread — with exactly the error the blocking
/// path produces.
pub fn verify_shard(data: &[u8]) -> Result<(), String> {
    if data.len() < 8 || &data[..4] != MAGIC {
        return Err("bad magic".into());
    }
    let body = &data[4..data.len() - 4];
    let stored_crc = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
    let crc = crc32(body);
    if crc != stored_crc {
        return Err(format!("crc mismatch: stored {stored_crc:08x} computed {crc:08x}"));
    }
    Ok(())
}

/// Reusable typed decode target for one shard: the structural half of
/// decoding writes into these buffers (cleared, capacity retained), so a
/// steady-state streaming reader performs **zero heap allocation per
/// shard** once every buffer has grown to the largest shard's working set.
/// [`ShardScratch::view`] then hands out borrowed [`TwoViewChunkRef`]s —
/// chunk slicing on top of them is allocation-free too.
#[derive(Debug, Default)]
pub struct ShardScratch {
    rows: usize,
    dims_a: usize,
    dims_b: usize,
    indptr_a: Vec<usize>,
    indices_a: Vec<u32>,
    values_a: Vec<f32>,
    indptr_b: Vec<usize>,
    indices_b: Vec<u32>,
    values_b: Vec<f32>,
    /// Times any buffer had to grow its capacity — the counter behind the
    /// zero-alloc-after-warmup assertion (stable once warmed up).
    pub grows: u64,
}

impl ShardScratch {
    pub fn new() -> ShardScratch {
        ShardScratch::default()
    }

    /// Borrowed chunk over the last decoded shard.
    pub fn view(&self) -> TwoViewChunkRef<'_> {
        TwoViewChunkRef {
            a: CsrRef {
                rows: self.rows,
                cols: self.dims_a,
                indptr: &self.indptr_a,
                indices: &self.indices_a,
                values: &self.values_a,
            },
            b: CsrRef {
                rows: self.rows,
                cols: self.dims_b,
                indptr: &self.indptr_b,
                indices: &self.indices_b,
                values: &self.values_b,
            },
        }
    }

    /// Payload bytes of the decoded shard (the coordinator's
    /// `shard_bytes_read` accounting unit: 8 bytes per nonzero).
    pub fn nnz_bytes(&self) -> u64 {
        (self.values_a.len() + self.values_b.len()) as u64 * 8
    }

    fn capacity_units(&self) -> usize {
        self.indptr_a.capacity()
            + self.indices_a.capacity()
            + self.values_a.capacity()
            + self.indptr_b.capacity()
            + self.indices_b.capacity()
            + self.values_b.capacity()
    }
}

/// Decode one view's payload into reusable buffers. Bulk chunked
/// conversions (not per-element cursor reads): decoding is pure validation
/// + offset computation over the already-read bytes, and in steady state
/// writes only into retained capacity.
fn decode_view_into(
    cur: &mut Cursor,
    rows: usize,
    cols: usize,
    indptr: &mut Vec<usize>,
    indices: &mut Vec<u32>,
    values: &mut Vec<f32>,
) -> Result<(), String> {
    let nnz = cur.u64()? as usize;
    let indptr_bytes = rows
        .checked_add(1)
        .and_then(|n| n.checked_mul(8))
        .ok_or_else(|| "row count overflows".to_string())?;
    let raw = cur.take(indptr_bytes)?;
    indptr.clear();
    indptr.extend(
        raw.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize),
    );
    let elem_bytes = nnz
        .checked_mul(4)
        .ok_or_else(|| "nnz overflows".to_string())?;
    let raw = cur.take(elem_bytes)?;
    indices.clear();
    indices.extend(
        raw.chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap())),
    );
    let raw = cur.take(elem_bytes)?;
    values.clear();
    values.extend(
        raw.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
    );
    // The on-disk indptr is relative (first entry 0); the view contract
    // wants nnz at the end. Both hold for well-formed shards and are
    // enforced by the CsrRef validation below via the same error strings
    // the owned decoder used.
    if indptr.first() != Some(&0) || indptr.last() != Some(&nnz) {
        return Err("indptr endpoints invalid".into());
    }
    let check = CsrRef {
        rows,
        cols,
        indptr: indptr.as_slice(),
        indices: indices.as_slice(),
        values: values.as_slice(),
    };
    check.validate()
}

/// Structural half of shard decoding, writing into `scratch`. The caller
/// is responsible for integrity ([`verify_shard`]) — the streaming
/// pipeline runs that on the I/O thread so the CRC sweep overlaps compute,
/// and this function then performs no second pass over the bytes.
pub fn decode_shard_body_into(data: &[u8], scratch: &mut ShardScratch) -> Result<(), String> {
    if data.len() < 8 || &data[..4] != MAGIC {
        return Err("bad magic".into());
    }
    let cap_before = scratch.capacity_units();
    let body = &data[4..data.len() - 4];
    let mut cur = Cursor { data: body, pos: 0 };
    let version = cur.u32()?;
    if version != VERSION {
        return Err(format!("unsupported shard version {version}"));
    }
    let rows = cur.u64()? as usize;
    let dims_a = cur.u64()? as usize;
    let dims_b = cur.u64()? as usize;
    decode_view_into(
        &mut cur,
        rows,
        dims_a,
        &mut scratch.indptr_a,
        &mut scratch.indices_a,
        &mut scratch.values_a,
    )?;
    decode_view_into(
        &mut cur,
        rows,
        dims_b,
        &mut scratch.indptr_b,
        &mut scratch.indices_b,
        &mut scratch.values_b,
    )?;
    if cur.pos != body.len() {
        return Err("trailing bytes in shard".into());
    }
    scratch.rows = rows;
    scratch.dims_a = dims_a;
    scratch.dims_b = dims_b;
    if scratch.capacity_units() != cap_before {
        scratch.grows += 1;
    }
    Ok(())
}

/// Integrity + structure decode into `scratch` (the blocking-path twin of
/// the I/O-thread-verified streaming decode).
pub fn decode_shard_into(data: &[u8], scratch: &mut ShardScratch) -> Result<(), String> {
    verify_shard(data)?;
    decode_shard_body_into(data, scratch)
}

/// Deserialize and validate a shard into owned storage. One-shot
/// convenience over [`decode_shard_into`]; streaming readers keep a
/// [`ShardScratch`] instead.
pub fn decode_shard(data: &[u8]) -> Result<TwoViewChunk, String> {
    let mut scratch = ShardScratch::new();
    decode_shard_into(data, &mut scratch)?;
    Ok(scratch.view().to_chunk())
}

/// Header + integrity summary of one shard file, computable even when the
/// payload is damaged — the debugging view behind `repro shard-info`,
/// used when a cluster worker rejects a shard at load time.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardInfo {
    pub bytes: usize,
    pub version: u32,
    pub rows: u64,
    pub dims_a: u64,
    pub dims_b: u64,
    /// View nonzero counts, when the file is long enough to carry them.
    pub nnz_a: Option<u64>,
    pub nnz_b: Option<u64>,
    pub crc_stored: u32,
    pub crc_computed: u32,
    /// What a full [`decode_shard`] says (`None` = decodes cleanly).
    pub error: Option<String>,
}

impl ShardInfo {
    pub fn crc_ok(&self) -> bool {
        self.crc_stored == self.crc_computed
    }
}

/// Inspect a shard file's header and integrity without requiring it to
/// decode. `Err` only when the file is too short to even carry a header —
/// corruption beyond that is *reported* (in [`ShardInfo::error`]) rather
/// than failing the inspection.
pub fn inspect_shard(data: &[u8]) -> Result<ShardInfo, String> {
    // magic + version + rows + dims_a + dims_b, plus the crc footer.
    const HEADER: usize = 4 + 4 + 8 + 8 + 8;
    if data.len() < 4 || &data[..4] != MAGIC {
        return Err("bad magic (not an rcca shard file)".to_string());
    }
    if data.len() < HEADER + 4 {
        return Err(format!(
            "file is {} bytes — too short for a shard header",
            data.len()
        ));
    }
    let u32_at = |pos: usize| u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap());
    let u64_at = |pos: usize| u64::from_le_bytes(data[pos..pos + 8].try_into().unwrap());
    let version = u32_at(4);
    let rows = u64_at(8);
    let dims_a = u64_at(16);
    let dims_b = u64_at(24);
    // View A starts right after the fixed header: nnz, indptr, indices,
    // values. View B's nnz sits after all of view A, if the file reaches.
    // Reads must stay inside the payload (everything before the 4-byte
    // CRC footer) — a truncated file must report "unreadable", never an
    // nnz assembled from CRC bytes. Checked arithmetic throughout: a
    // corrupt header can claim absurd rows/nnz, and the inspector must
    // report, not overflow.
    let payload_end = data.len() - 4;
    let nnz_a = (payload_end >= HEADER + 8).then(|| u64_at(HEADER));
    let nnz_b = nnz_a.and_then(|na| {
        let indptr = (rows as usize).checked_add(1)?.checked_mul(8)?;
        let view_a = 8usize
            .checked_add(indptr)?
            .checked_add((na as usize).checked_mul(8)?)?;
        let pos = HEADER.checked_add(view_a)?;
        (payload_end >= pos.checked_add(8)?).then(|| u64_at(pos))
    });
    let crc_stored = u32_at(data.len() - 4);
    let crc_computed = crc32(&data[4..data.len() - 4]);
    Ok(ShardInfo {
        bytes: data.len(),
        version,
        rows,
        dims_a,
        dims_b,
        nnz_a,
        nnz_b,
        crc_stored,
        crc_computed,
        error: decode_shard(data).err(),
    })
}

/// Writer that splits a stream of row-aligned chunks into shard files.
pub struct ShardWriter {
    dir: PathBuf,
    rows_per_shard: usize,
    shards_written: usize,
    total_rows: usize,
    dims_a: usize,
    dims_b: usize,
}

impl ShardWriter {
    pub fn create(dir: &Path, rows_per_shard: usize) -> std::io::Result<ShardWriter> {
        fs::create_dir_all(dir)?;
        Ok(ShardWriter {
            dir: dir.to_path_buf(),
            rows_per_shard,
            shards_written: 0,
            total_rows: 0,
            dims_a: 0,
            dims_b: 0,
        })
    }

    /// Write a full dataset by slicing row ranges into shards.
    pub fn write_dataset(&mut self, a: &Csr, b: &Csr) -> std::io::Result<()> {
        assert_eq!(a.rows, b.rows);
        self.dims_a = a.cols;
        self.dims_b = b.cols;
        let mut lo = 0;
        while lo < a.rows {
            let hi = (lo + self.rows_per_shard).min(a.rows);
            let chunk = TwoViewChunk {
                a: a.slice_rows(lo, hi),
                b: b.slice_rows(lo, hi),
            };
            let bytes = encode_shard(&chunk);
            let path = self.dir.join(format!("shard-{:05}.bin", self.shards_written));
            let tmp = self.dir.join(format!(".shard-{:05}.tmp", self.shards_written));
            // Write-then-rename so a crashed writer never leaves a torn shard
            // under the final name.
            fs::File::create(&tmp)?.write_all(&bytes)?;
            fs::rename(&tmp, &path)?;
            self.shards_written += 1;
            self.total_rows += hi - lo;
            lo = hi;
        }
        self.write_meta()
    }

    fn write_meta(&self) -> std::io::Result<()> {
        let mut meta = Json::obj();
        meta.set("format", jstr("rcca-shards-v1"))
            .set("shards", jnum(self.shards_written as f64))
            .set("rows", jnum(self.total_rows as f64))
            .set("dims_a", jnum(self.dims_a as f64))
            .set("dims_b", jnum(self.dims_b as f64))
            .set("rows_per_shard", jnum(self.rows_per_shard as f64));
        fs::write(self.dir.join("meta.json"), meta.to_string_pretty())
    }
}

/// Read access to a shard directory.
#[derive(Debug, Clone)]
pub struct ShardStore {
    pub dir: PathBuf,
    pub shards: usize,
    pub rows: usize,
    pub dims_a: usize,
    pub dims_b: usize,
}

impl ShardStore {
    pub fn open(dir: &Path) -> Result<ShardStore, String> {
        let meta_text = fs::read_to_string(dir.join("meta.json"))
            .map_err(|e| format!("cannot read meta.json: {e}"))?;
        let meta = crate::util::json::parse(&meta_text).map_err(|e| e.to_string())?;
        let get = |k: &str| -> Result<usize, String> {
            meta.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| format!("meta.json missing '{k}'"))
        };
        Ok(ShardStore {
            dir: dir.to_path_buf(),
            shards: get("shards")?,
            rows: get("rows")?,
            dims_a: get("dims_a")?,
            dims_b: get("dims_b")?,
        })
    }

    pub fn shard_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("shard-{i:05}.bin"))
    }

    /// Load and validate one shard (shim over [`ShardStore::load_into`]
    /// with a throwaway buffer — hot callers reuse one instead).
    pub fn load(&self, i: usize) -> Result<TwoViewChunk, String> {
        let mut bytes = Vec::new();
        self.load_into(i, &mut bytes)
    }

    /// Load and validate one shard, reusing `bytes` as the read buffer
    /// (cleared and refilled; its capacity is retained across calls, so a
    /// steady-state caller stops allocating once the buffer has grown to
    /// the largest shard).
    pub fn load_into(&self, i: usize, bytes: &mut Vec<u8>) -> Result<TwoViewChunk, String> {
        self.read_bytes_into(i, bytes)?;
        decode_shard(bytes).map_err(|e| format!("shard {i}: {e}"))
    }

    /// Read one shard's raw bytes into a reused buffer without decoding —
    /// the prefetch pipeline's I/O primitive. Sized from file metadata and
    /// filled with `read_exact`, so a warm buffer is never re-allocated
    /// (`read_to_end` would reserve past the end to probe for EOF).
    pub fn read_bytes_into(&self, i: usize, bytes: &mut Vec<u8>) -> Result<(), String> {
        assert!(i < self.shards, "shard index out of range");
        let path = self.shard_path(i);
        let mut f = fs::File::open(&path).map_err(|e| format!("open shard {i}: {e}"))?;
        let len = f
            .metadata()
            .map_err(|e| format!("stat shard {i}: {e}"))?
            .len() as usize;
        bytes.clear();
        bytes.resize(len, 0);
        f.read_exact(bytes)
            .map_err(|e| format!("read shard {i}: {e}"))?;
        Ok(())
    }

    /// Which shards are actually present on disk. `meta.json` fixes the
    /// dataset's *shape*; the shard files fix this node's *holdings* — a
    /// cluster replica target legitimately starts with a subset and
    /// mirrors the rest over the wire.
    pub fn present_shards(&self) -> Vec<u32> {
        (0..self.shards)
            .filter(|&i| self.shard_path(i).exists())
            .map(|i| i as u32)
            .collect()
    }

    /// Install one shard received over the wire: verify integrity first,
    /// then write tmp+rename so a crash never leaves a torn shard under
    /// the final name (the same idiom [`ShardWriter`] uses).
    pub fn install_shard(&self, i: usize, bytes: &[u8]) -> Result<(), String> {
        if i >= self.shards {
            return Err(format!(
                "shard index {i} out of range (store has {})",
                self.shards
            ));
        }
        verify_shard(bytes).map_err(|e| format!("shard {i}: {e}"))?;
        let tmp = self.dir.join(format!(".shard-{i:05}.tmp"));
        fs::write(&tmp, bytes).map_err(|e| format!("write shard {i}: {e}"))?;
        fs::rename(&tmp, self.shard_path(i)).map_err(|e| format!("rename shard {i}: {e}"))
    }

    /// Load all shards concatenated (test-scale convenience).
    pub fn load_all(&self) -> Result<TwoViewChunk, String> {
        let mut chunks = Vec::new();
        for i in 0..self.shards {
            chunks.push(self.load(i)?);
        }
        Ok(concat_chunks(&chunks))
    }
}

/// Concatenate row-aligned chunks (reduce-side helper and test utility).
pub fn concat_chunks(chunks: &[TwoViewChunk]) -> TwoViewChunk {
    assert!(!chunks.is_empty());
    let concat = |pick: &dyn Fn(&TwoViewChunk) -> &Csr| -> Csr {
        let cols = pick(&chunks[0]).cols;
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for ch in chunks {
            let c = pick(ch);
            assert_eq!(c.cols, cols);
            let base = *indptr.last().unwrap();
            indptr.extend(c.indptr[1..].iter().map(|p| p + base));
            indices.extend_from_slice(&c.indices);
            values.extend_from_slice(&c.values);
        }
        Csr {
            rows: indptr.len() - 1,
            cols,
            indptr,
            indices,
            values,
        }
    };
    TwoViewChunk {
        a: concat(&|c| &c.a),
        b: concat(&|c| &c.b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthparl::{SynthParl, SynthParlConfig};

    fn tiny_dataset() -> (Csr, Csr) {
        let d = SynthParl::generate(SynthParlConfig {
            n: 300,
            dims: 64,
            topics: 4,
            words_per_topic: 10,
            background_words: 20,
            mean_len: 6.0,
            seed: 5,
            ..Default::default()
        });
        (d.a, d.b)
    }

    #[test]
    fn crc32_known_vector() {
        // Standard test vector: crc32("123456789") = 0xcbf43926.
        assert_eq!(crc32(b"123456789"), 0xcbf43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn shard_roundtrip() {
        let (a, b) = tiny_dataset();
        let chunk = TwoViewChunk { a, b };
        let bytes = encode_shard(&chunk);
        let back = decode_shard(&bytes).unwrap();
        assert_eq!(chunk, back);
    }

    #[test]
    fn corruption_detected() {
        let (a, b) = tiny_dataset();
        let mut bytes = encode_shard(&TwoViewChunk { a, b });
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        let err = decode_shard(&bytes).unwrap_err();
        assert!(err.contains("crc") || err.contains("indices") || err.contains("indptr"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let (a, b) = tiny_dataset();
        let bytes = encode_shard(&TwoViewChunk { a, b });
        assert!(decode_shard(&bytes[..bytes.len() - 10]).is_err());
        assert!(decode_shard(&bytes[..3]).is_err());
    }

    #[test]
    fn bad_magic_detected() {
        let mut bytes = encode_shard(&TwoViewChunk {
            a: tiny_dataset().0,
            b: tiny_dataset().1,
        });
        bytes[0] = b'X';
        assert_eq!(decode_shard(&bytes).unwrap_err(), "bad magic");
    }

    #[test]
    fn store_roundtrip_with_sharding() {
        let (a, b) = tiny_dataset();
        let dir = std::env::temp_dir().join("rcca_shard_test");
        let _ = fs::remove_dir_all(&dir);
        let mut w = ShardWriter::create(&dir, 64).unwrap();
        w.write_dataset(&a, &b).unwrap();
        let store = ShardStore::open(&dir).unwrap();
        assert_eq!(store.rows, 300);
        assert_eq!(store.shards, 5); // ceil(300/64)
        assert_eq!(store.dims_a, 64);
        // Per-shard rows sum to total; concatenation reproduces the dataset.
        let all = store.load_all().unwrap();
        assert_eq!(all.a, a);
        assert_eq!(all.b, b);
        // Row alignment: every shard has equal rows in both views.
        for i in 0..store.shards {
            let ch = store.load(i).unwrap();
            assert_eq!(ch.a.rows, ch.b.rows);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn scratch_decode_matches_owned_decode_and_reuses_capacity() {
        let (a, b) = tiny_dataset();
        let dir = std::env::temp_dir().join("rcca_shard_scratch");
        let _ = fs::remove_dir_all(&dir);
        let mut w = ShardWriter::create(&dir, 64).unwrap();
        w.write_dataset(&a, &b).unwrap();
        let store = ShardStore::open(&dir).unwrap();
        let mut scratch = ShardScratch::new();
        let mut bytes = Vec::new();
        // Warmup sweep: decode every shard once through the scratch.
        for i in 0..store.shards {
            store.read_bytes_into(i, &mut bytes).unwrap();
            decode_shard_into(&bytes, &mut scratch).unwrap();
            let owned = store.load(i).unwrap();
            // The borrowed view is the owned chunk, bitwise.
            assert_eq!(scratch.view().to_chunk(), owned);
            assert_eq!(scratch.view().rows(), owned.rows());
            assert_eq!(scratch.nnz_bytes(), (owned.a.nnz() + owned.b.nnz()) as u64 * 8);
            // Chunk slices off the view match owned slices.
            let rows = owned.rows();
            let mid = rows / 2;
            assert_eq!(
                scratch.view().slice_rows(0, mid).to_chunk(),
                TwoViewChunk {
                    a: owned.a.slice_rows(0, mid),
                    b: owned.b.slice_rows(0, mid),
                }
            );
        }
        // Steady state: a second sweep grows nothing.
        let grows = scratch.grows;
        let byte_cap = bytes.capacity();
        for i in 0..store.shards {
            store.read_bytes_into(i, &mut bytes).unwrap();
            decode_shard_into(&bytes, &mut scratch).unwrap();
        }
        assert_eq!(scratch.grows, grows, "scratch must not grow after warmup");
        assert_eq!(bytes.capacity(), byte_cap, "read buffer must not grow after warmup");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_shard_splits_integrity_from_structure() {
        let (a, b) = tiny_dataset();
        let mut bytes = encode_shard(&TwoViewChunk { a, b });
        verify_shard(&bytes).unwrap();
        // Same corrupt input produces the same error through the verify
        // half as through the one-shot decoder (the streaming pipeline
        // surfaces verify errors from I/O threads).
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        let via_verify = verify_shard(&bytes).unwrap_err();
        let via_decode = decode_shard(&bytes).unwrap_err();
        assert_eq!(via_verify, via_decode);
        assert!(verify_shard(b"XX").is_err());
    }

    #[test]
    fn load_into_reuses_buffer_and_matches_load() {
        let (a, b) = tiny_dataset();
        let dir = std::env::temp_dir().join("rcca_shard_load_into");
        let _ = fs::remove_dir_all(&dir);
        let mut w = ShardWriter::create(&dir, 100).unwrap();
        w.write_dataset(&a, &b).unwrap();
        let store = ShardStore::open(&dir).unwrap();
        let mut buf = Vec::new();
        for i in 0..store.shards {
            assert_eq!(store.load_into(i, &mut buf).unwrap(), store.load(i).unwrap());
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn partial_store_reports_holdings_and_installs_shards() {
        let (a, b) = tiny_dataset();
        let dir = std::env::temp_dir().join("rcca_shard_partial");
        let _ = fs::remove_dir_all(&dir);
        let mut w = ShardWriter::create(&dir, 64).unwrap();
        w.write_dataset(&a, &b).unwrap();
        let store = ShardStore::open(&dir).unwrap();
        assert_eq!(store.present_shards(), vec![0, 1, 2, 3, 4]);
        // Drop two shard files: the store still opens (meta is intact) and
        // reports exactly what is left.
        let evicted = fs::read(store.shard_path(1)).unwrap();
        fs::remove_file(store.shard_path(1)).unwrap();
        fs::remove_file(store.shard_path(3)).unwrap();
        let partial = ShardStore::open(&dir).unwrap();
        assert_eq!(partial.present_shards(), vec![0, 2, 4]);
        assert!(partial.load(1).is_err());
        // Mirroring the missing shard back restores it bit-for-bit.
        partial.install_shard(1, &evicted).unwrap();
        assert_eq!(partial.present_shards(), vec![0, 1, 2, 4]);
        assert_eq!(fs::read(partial.shard_path(1)).unwrap(), evicted);
        partial.load(1).unwrap();
        // Corrupt bytes are rejected before touching the final name.
        let mut bad = evicted.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0xff;
        let err = partial.install_shard(3, &bad).unwrap_err();
        assert!(err.contains("crc"), "{err}");
        assert!(!partial.shard_path(3).exists());
        assert!(partial.install_shard(99, &evicted).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concat_of_slices_is_identity() {
        let (a, b) = tiny_dataset();
        let c1 = TwoViewChunk {
            a: a.slice_rows(0, 100),
            b: b.slice_rows(0, 100),
        };
        let c2 = TwoViewChunk {
            a: a.slice_rows(100, 300),
            b: b.slice_rows(100, 300),
        };
        let whole = concat_chunks(&[c1, c2]);
        assert_eq!(whole.a, a);
        assert_eq!(whole.b, b);
    }

    #[test]
    fn open_missing_dir_errors() {
        assert!(ShardStore::open(Path::new("/nonexistent/rcca")).is_err());
    }

    #[test]
    fn inspect_reports_clean_shards() {
        let (a, b) = tiny_dataset();
        let (na, nb) = (a.nnz() as u64, b.nnz() as u64);
        let bytes = encode_shard(&TwoViewChunk { a, b });
        let info = inspect_shard(&bytes).unwrap();
        assert_eq!(info.version, VERSION);
        assert_eq!((info.rows, info.dims_a, info.dims_b), (300, 64, 64));
        assert_eq!(info.nnz_a, Some(na));
        assert_eq!(info.nnz_b, Some(nb));
        assert!(info.crc_ok());
        assert_eq!(info.error, None);
    }

    #[test]
    fn inspect_reports_corruption_without_failing() {
        let (a, b) = tiny_dataset();
        let mut bytes = encode_shard(&TwoViewChunk { a, b });
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        let info = inspect_shard(&bytes).unwrap();
        assert!(!info.crc_ok());
        assert!(info.error.is_some());
        // Header fields still readable for debugging.
        assert_eq!(info.rows, 300);
        // Truly hopeless inputs are inspection errors.
        assert!(inspect_shard(b"RC").is_err());
        assert!(inspect_shard(b"XXXX............").is_err());
        assert!(inspect_shard(&bytes[..10]).is_err());
    }
}
