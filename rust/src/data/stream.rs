//! Out-of-core shard streaming: pooled read buffers + a prefetch pipeline.
//!
//! The uncached (out-of-core) regime re-reads every shard from disk on
//! every pass. Before this module, that path was fully serial per shard:
//! blocking `read_to_end` → allocating decode → compute, with the disk
//! idle while kernels ran and the CPU idle while the disk ran. The
//! [`ShardStreamer`] overlaps the two: a small pool of I/O threads reads
//! (and CRC-verifies — see [`crate::data::shards::verify_shard`]) shards
//! ahead of the compute threads into pooled, reusable byte buffers, with a
//! bounded number of buffers in flight so prefetching cannot blow the
//! memory budget that made the data out-of-core in the first place.
//!
//! Correctness stance: prefetching changes *when* bytes are read, never
//! *what* is computed — the consumer receives exactly the file's bytes and
//! decodes them on its own thread, so fits are bitwise identical across
//! every `prefetch_depth`/`io_threads` setting, including the fully
//! blocking `prefetch_depth = 0` mode (pinned by coordinator tests). A
//! fetch for a shard the pipeline does not have planned (a retry after a
//! fault, or an unplanned probe) falls back to a direct synchronous read,
//! so no caller can deadlock on the pipeline's bounded slots.

use super::shards::{verify_shard, ShardStore};
use std::collections::{HashMap, HashSet, VecDeque};
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Streaming knobs (the out-of-core pipeline's public surface; exposed via
/// `ShardedPassConfig`, engine specs, and `repro fit`).
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Shards buffered or in flight ahead of the consumers. 0 disables the
    /// pipeline entirely: every fetch is a blocking read on the calling
    /// thread (still through the buffer pool).
    pub prefetch_depth: usize,
    /// Reader threads feeding the pipeline (only meaningful with
    /// `prefetch_depth > 0`; more than `prefetch_depth` would idle).
    pub io_threads: usize,
    /// Peak-memory budget for *parked* (read but not yet consumed) shard
    /// bytes, in MiB. 0 = bounded by `prefetch_depth` alone. The budget is
    /// a soft high-water mark: a read already in flight when the mark is
    /// crossed still parks.
    pub max_buffered_mb: usize,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig {
            prefetch_depth: 2,
            io_threads: 1,
            max_buffered_mb: 0,
        }
    }
}

/// Reusable byte buffers with allocation accounting. `get` hands out a
/// cleared buffer (capacity retained from earlier use); `put` returns it.
/// After warmup — every buffer grown to the largest shard — the pool
/// serves the steady state with zero heap traffic, and the counters prove
/// it (the zero-alloc assertion in the coordinator tests).
#[derive(Debug, Default)]
pub struct BufferPool {
    free: Mutex<Vec<Vec<u8>>>,
    /// Fresh buffers created (pool was empty at `get`).
    pub allocs: AtomicU64,
    /// Buffers served from the free list.
    pub reuses: AtomicU64,
    /// Times a served buffer's capacity grew while in use (reported back
    /// by the streamer after each read).
    pub grows: AtomicU64,
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    fn get(&self) -> Vec<u8> {
        match self.free.lock().unwrap().pop() {
            Some(b) => {
                self.reuses.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.allocs.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    fn put(&self, mut b: Vec<u8>) {
        b.clear();
        self.free.lock().unwrap().push(b);
    }
}

/// A shard's bytes on loan from the pool; returns to the pool on drop.
pub struct PooledBytes {
    buf: Option<Vec<u8>>,
    pool: Arc<BufferPool>,
}

impl PooledBytes {
    fn new(buf: Vec<u8>, pool: Arc<BufferPool>) -> PooledBytes {
        PooledBytes {
            buf: Some(buf),
            pool,
        }
    }
}

impl Deref for PooledBytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.buf.as_deref().expect("buffer present until drop")
    }
}

impl Drop for PooledBytes {
    fn drop(&mut self) {
        if let Some(b) = self.buf.take() {
            self.pool.put(b);
        }
    }
}

/// Wait-free counters describing pipeline behavior (snapshot via
/// [`ShardStreamer::counters`]).
#[derive(Debug, Default)]
pub struct StreamStats {
    /// Fetches served from a parked prefetched buffer (possibly after a
    /// wait for the in-flight read).
    pub prefetch_hits: AtomicU64,
    /// Fetches that fell back to a direct synchronous read (unplanned
    /// shard: retries, probes, or `prefetch_depth = 0`).
    pub prefetch_misses: AtomicU64,
    /// Nanoseconds I/O threads spent reading + verifying.
    pub io_read_nanos: AtomicU64,
    /// Nanoseconds consumers spent blocked waiting on the pipeline.
    pub wait_nanos: AtomicU64,
}

/// Point-in-time snapshot of the streaming path's allocation and hit-rate
/// counters (the "workspace/pool counters" the zero-alloc assertion reads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamCounters {
    pub buf_allocs: u64,
    pub buf_reuses: u64,
    pub buf_grows: u64,
    pub prefetch_hits: u64,
    pub prefetch_misses: u64,
}

/// One pass's read-ahead plan plus the parking lot for completed reads.
#[derive(Default)]
struct Plan {
    /// Bumped by [`ShardStreamer::plan`]; a read completing under an older
    /// epoch is discarded (its buffer returns to the pool).
    epoch: u64,
    /// Shards not yet picked up by an I/O thread, in consumption order.
    queue: VecDeque<usize>,
    /// Shards an I/O thread is currently reading.
    in_flight: Vec<usize>,
    /// Membership index over `queue` + `in_flight`: shards the pipeline
    /// still owes a read for. Keeps the consumer's planned-check O(1)
    /// instead of rescanning the queue under the mutex on every wakeup.
    pending: HashSet<usize>,
    /// Completed reads awaiting their consumer. An `Err` parks the typed
    /// load error (open/read/CRC), which the consumer surfaces exactly as
    /// the blocking path would.
    parked: HashMap<usize, Result<Vec<u8>, String>>,
    parked_bytes: usize,
    shutdown: bool,
}

struct Shared {
    plan: Mutex<Plan>,
    /// Signalled when a read parks (consumers waiting in `fetch`).
    ready: Condvar,
    /// Signalled when work or slots appear (I/O threads waiting to read).
    work: Condvar,
}

/// Prefetching shard reader. Construction spawns the I/O threads (none
/// when `prefetch_depth` is 0); [`ShardStreamer::plan`] installs the pass
/// order; [`ShardStreamer::fetch`] hands each consumer its shard's bytes.
pub struct ShardStreamer {
    store: ShardStore,
    cfg: StreamConfig,
    pool: Arc<BufferPool>,
    stats: Arc<StreamStats>,
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
}

/// Read + integrity-verify one shard into `buf`, with the same error
/// strings [`ShardStore::load`] produces for the same failures.
fn read_and_verify(
    store: &ShardStore,
    shard: usize,
    buf: &mut Vec<u8>,
    pool: &BufferPool,
) -> Result<(), String> {
    let cap = buf.capacity();
    store.read_bytes_into(shard, buf)?;
    if buf.capacity() != cap {
        pool.grows.fetch_add(1, Ordering::Relaxed);
    }
    verify_shard(buf).map_err(|e| format!("shard {shard}: {e}"))
}

impl ShardStreamer {
    pub fn new(store: ShardStore, cfg: StreamConfig) -> ShardStreamer {
        let pool = Arc::new(BufferPool::new());
        let stats = Arc::new(StreamStats::default());
        let shared = Arc::new(Shared {
            plan: Mutex::new(Plan::default()),
            ready: Condvar::new(),
            work: Condvar::new(),
        });
        let mut threads = Vec::new();
        if cfg.prefetch_depth > 0 {
            // More readers than read-ahead slots would never all run.
            let n = cfg.io_threads.clamp(1, cfg.prefetch_depth);
            for i in 0..n {
                let store = store.clone();
                let pool = Arc::clone(&pool);
                let stats = Arc::clone(&stats);
                let shared = Arc::clone(&shared);
                let depth = cfg.prefetch_depth;
                let budget = cfg.max_buffered_mb.saturating_mul(1 << 20);
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("rcca-io-{i}"))
                        .spawn(move || io_loop(&store, &pool, &stats, &shared, depth, budget))
                        .expect("spawn io thread"),
                );
            }
        }
        ShardStreamer {
            store,
            cfg,
            pool,
            stats,
            shared,
            threads,
        }
    }

    /// Install the read-ahead order for the coming pass, discarding any
    /// leftovers from the previous one. No-op in blocking mode.
    pub fn plan(&self, shards: &[usize]) {
        if self.threads.is_empty() {
            return;
        }
        let mut plan = self.shared.plan.lock().unwrap();
        plan.epoch += 1;
        plan.queue.clear();
        plan.queue.extend(shards.iter().copied());
        plan.in_flight.clear();
        plan.pending.clear();
        plan.pending.extend(shards.iter().copied());
        for (_, res) in plan.parked.drain() {
            if let Ok(buf) = res {
                self.pool.put(buf);
            }
        }
        plan.parked_bytes = 0;
        drop(plan);
        self.shared.work.notify_all();
    }

    /// Obtain one shard's verified bytes: from the pipeline when planned
    /// (blocking until its read completes), otherwise via a direct
    /// synchronous read. Never deadlocks: an unplanned shard cannot wait.
    pub fn fetch(&self, shard: usize) -> Result<PooledBytes, String> {
        if self.threads.is_empty() {
            self.stats.prefetch_misses.fetch_add(1, Ordering::Relaxed);
            return self.direct(shard);
        }
        let wait_t = Instant::now();
        let mut plan = self.shared.plan.lock().unwrap();
        loop {
            if let Some(res) = plan.parked.remove(&shard) {
                if let Ok(buf) = &res {
                    plan.parked_bytes -= buf.len();
                }
                drop(plan);
                self.shared.work.notify_all();
                self.stats.prefetch_hits.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .wait_nanos
                    .fetch_add(wait_t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                return res.map(|buf| PooledBytes::new(buf, Arc::clone(&self.pool)));
            }
            if !plan.pending.contains(&shard) {
                drop(plan);
                self.stats.prefetch_misses.fetch_add(1, Ordering::Relaxed);
                return self.direct(shard);
            }
            plan = self.shared.ready.wait(plan).unwrap();
        }
    }

    fn direct(&self, shard: usize) -> Result<PooledBytes, String> {
        let mut buf = self.pool.get();
        match read_and_verify(&self.store, shard, &mut buf, &self.pool) {
            Ok(()) => Ok(PooledBytes::new(buf, Arc::clone(&self.pool))),
            Err(e) => {
                self.pool.put(buf);
                Err(e)
            }
        }
    }

    pub fn counters(&self) -> StreamCounters {
        StreamCounters {
            buf_allocs: self.pool.allocs.load(Ordering::Relaxed),
            buf_reuses: self.pool.reuses.load(Ordering::Relaxed),
            buf_grows: self.pool.grows.load(Ordering::Relaxed),
            prefetch_hits: self.stats.prefetch_hits.load(Ordering::Relaxed),
            prefetch_misses: self.stats.prefetch_misses.load(Ordering::Relaxed),
        }
    }

    pub fn stats(&self) -> &StreamStats {
        &self.stats
    }

    pub fn config(&self) -> &StreamConfig {
        &self.cfg
    }
}

impl Drop for ShardStreamer {
    fn drop(&mut self) {
        {
            let mut plan = self.shared.plan.lock().unwrap();
            plan.shutdown = true;
        }
        self.shared.work.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn io_loop(
    store: &ShardStore,
    pool: &Arc<BufferPool>,
    stats: &StreamStats,
    shared: &Shared,
    depth: usize,
    budget_bytes: usize,
) {
    loop {
        // Claim the next planned shard once a read-ahead slot is free.
        let (shard, epoch) = {
            let mut plan = shared.plan.lock().unwrap();
            loop {
                if plan.shutdown {
                    return;
                }
                let outstanding = plan.parked.len() + plan.in_flight.len();
                let budget_ok = budget_bytes == 0 || plan.parked_bytes < budget_bytes;
                if outstanding < depth && budget_ok && !plan.queue.is_empty() {
                    let s = plan.queue.pop_front().expect("checked non-empty");
                    plan.in_flight.push(s);
                    break (s, plan.epoch);
                }
                plan = shared.work.wait(plan).unwrap();
            }
        };
        let mut buf = pool.get();
        let t = Instant::now();
        let res = read_and_verify(store, shard, &mut buf, pool);
        stats
            .io_read_nanos
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let mut plan = shared.plan.lock().unwrap();
        if plan.epoch != epoch {
            // The plan moved on mid-read; nobody wants these bytes.
            drop(plan);
            pool.put(buf);
            continue;
        }
        if let Some(pos) = plan.in_flight.iter().position(|&s| s == shard) {
            plan.in_flight.swap_remove(pos);
        }
        plan.pending.remove(&shard);
        match res {
            Ok(()) => {
                plan.parked_bytes += buf.len();
                plan.parked.insert(shard, Ok(buf));
            }
            Err(e) => {
                plan.parked.insert(shard, Err(e));
                pool.put(buf);
            }
        }
        drop(plan);
        shared.ready.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shards::{decode_shard, ShardWriter, TwoViewChunk};
    use crate::data::synthparl::{SynthParl, SynthParlConfig};
    use std::path::PathBuf;

    fn store(tag: &str) -> ShardStore {
        let d = SynthParl::generate(SynthParlConfig {
            n: 260,
            dims: 40,
            topics: 4,
            words_per_topic: 8,
            background_words: 12,
            mean_len: 6.0,
            seed: 29,
            ..Default::default()
        });
        let dir = PathBuf::from(std::env::temp_dir()).join(format!("rcca_stream_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        let mut w = ShardWriter::create(&dir, 48).unwrap();
        w.write_dataset(&d.a, &d.b).unwrap();
        ShardStore::open(&dir).unwrap()
    }

    fn fetch_all(streamer: &ShardStreamer, store: &ShardStore) -> Vec<TwoViewChunk> {
        let order: Vec<usize> = (0..store.shards).collect();
        streamer.plan(&order);
        order
            .iter()
            .map(|&i| {
                let bytes = streamer.fetch(i).unwrap();
                decode_shard(&bytes).unwrap()
            })
            .collect()
    }

    #[test]
    fn prefetched_bytes_equal_blocking_bytes() {
        let st = store("equal");
        for (depth, io) in [(0usize, 1usize), (1, 1), (3, 2), (8, 3)] {
            let streamer = ShardStreamer::new(
                st.clone(),
                StreamConfig {
                    prefetch_depth: depth,
                    io_threads: io,
                    max_buffered_mb: 0,
                },
            );
            let got = fetch_all(&streamer, &st);
            for (i, chunk) in got.iter().enumerate() {
                assert_eq!(*chunk, st.load(i).unwrap(), "depth {depth} io {io} shard {i}");
            }
            let c = streamer.counters();
            if depth == 0 {
                assert_eq!(c.prefetch_hits, 0);
                assert_eq!(c.prefetch_misses, st.shards as u64);
            } else {
                assert_eq!(c.prefetch_hits, st.shards as u64);
                assert_eq!(c.prefetch_misses, 0);
            }
        }
    }

    #[test]
    fn buffers_are_pooled_across_passes() {
        let st = store("pooled");
        let streamer = ShardStreamer::new(
            st.clone(),
            StreamConfig {
                prefetch_depth: 2,
                io_threads: 1,
                max_buffered_mb: 0,
            },
        );
        fetch_all(&streamer, &st); // warmup
        let warm = streamer.counters();
        for _ in 0..3 {
            fetch_all(&streamer, &st);
        }
        let c = streamer.counters();
        assert_eq!(c.buf_allocs, warm.buf_allocs, "no new buffers after warmup");
        assert_eq!(c.buf_grows, warm.buf_grows, "no buffer growth after warmup");
        assert!(c.buf_reuses > warm.buf_reuses);
    }

    #[test]
    fn unplanned_fetch_falls_back_to_direct_read() {
        let st = store("fallback");
        let streamer = ShardStreamer::new(st.clone(), StreamConfig::default());
        // No plan installed at all: every fetch is a miss, still correct.
        let chunk = decode_shard(&streamer.fetch(1).unwrap()).unwrap();
        assert_eq!(chunk, st.load(1).unwrap());
        // Plan a later window, then ask for something outside it (retry
        // shape): direct read, no deadlock.
        streamer.plan(&[2, 3]);
        let again = decode_shard(&streamer.fetch(0).unwrap()).unwrap();
        assert_eq!(again, st.load(0).unwrap());
        assert!(streamer.counters().prefetch_misses >= 2);
    }

    #[test]
    fn read_errors_surface_from_io_threads() {
        let st = store("ioerr");
        // Corrupt shard 1 on disk (flip a payload byte).
        let path = st.shard_path(1);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let blocking = ShardStreamer::new(
            st.clone(),
            StreamConfig {
                prefetch_depth: 0,
                io_threads: 1,
                max_buffered_mb: 0,
            },
        );
        let prefetched = ShardStreamer::new(
            st.clone(),
            StreamConfig {
                prefetch_depth: 2,
                io_threads: 2,
                max_buffered_mb: 0,
            },
        );
        let order: Vec<usize> = (0..st.shards).collect();
        blocking.plan(&order);
        prefetched.plan(&order);
        let want = blocking.fetch(1).map(|_| ()).unwrap_err();
        let got = prefetched.fetch(1).map(|_| ()).unwrap_err();
        // The prefetch thread's verify failure is the blocking error,
        // verbatim.
        assert_eq!(got, want);
        assert!(got.contains("shard 1"), "{got}");
        assert!(got.contains("crc mismatch"), "{got}");
        // Healthy shards around it still stream.
        assert!(prefetched.fetch(0).is_ok());
        assert!(prefetched.fetch(2).is_ok());
    }

    #[test]
    fn budget_bounds_parked_bytes() {
        let st = store("budget");
        // 1 MiB budget far exceeds these tiny shards — the pipeline must
        // still complete; this exercises the budget arithmetic, the
        // depth bound covers the tight case.
        let streamer = ShardStreamer::new(
            st.clone(),
            StreamConfig {
                prefetch_depth: 4,
                io_threads: 2,
                max_buffered_mb: 1,
            },
        );
        let got = fetch_all(&streamer, &st);
        assert_eq!(got.len(), st.shards);
    }
}
