//! `repro` — the RandomizedCCA system launcher.
//!
//! Subcommands map 1:1 to the paper's artifacts (DESIGN.md §6):
//!   gen        generate + shard a SynthParl workload
//!   rcca       run RandomizedCCA end to end (any engine), report objective
//!   horst      run the Horst baseline (optionally rcca-initialized)
//!   spectrum   E1 / Figure 1 — two-pass randomized SVD spectrum
//!   fig2a      E2 / Figure 2a — (q, p) sweep vs Horst reference
//!   table2b    E3 / Table 2b — times + train/test + Horst rows
//!   nu-sweep   E4 / Figure 3 — ν sensitivity, rcca vs Horst
//!   serve      HTTP model server over a saved model (rcca::serve)
//!   transform  offline projection of a dataset through a saved model
//!
//! Every experiment writes its JSON twin under --report-dir. All fitting
//! goes through the `rcca::api` session layer (builder → fit →
//! FittedModel); `rcca --save` persists the fitted model as JSON for reuse
//! by `serve`/`transform` or any other process
//! (`rcca::api::FittedModel::load`).

use rcca::api::{Backend, Cca, Engine, FittedModel, Provenance, Solver};
use rcca::bench::Report;
use rcca::cluster::{ChaosPlan, Checkpoint, ClusterConfig, Worker, WorkerConfig};
use rcca::data::shards::TwoViewChunk;
use rcca::data::synthparl::SynthParl;
use rcca::experiments::{self, Scale, Workload};
use rcca::lifecycle::{Daemon, DaemonConfig, Ingestor, Manifest, Retention, Tick};
use rcca::serve::{proto, Server, ServerConfig, View};
use rcca::telemetry;
use rcca::util::cli::{Args, Spec};
use rcca::util::timer::Timer;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "repro — RandomizedCCA reproduction (Mineiro & Karampatziakis, 2014)\n\
     \n\
     USAGE: repro <subcommand> [--flags]\n\
     \n\
     SUBCOMMANDS:\n\
       gen        generate + shard a SynthParl workload\n\
       rcca       run RandomizedCCA, print objective + feasibility\n\
       horst      run the Horst baseline\n\
       spectrum   Figure 1 — spectrum of (1/n) A'B\n\
       fig2a      Figure 2a — objective vs (q, p) with Horst reference\n\
       table2b    Table 2b — times, train/test, Horst rows\n\
       nu-sweep   Figure 3 — nu sensitivity\n\
       serve      HTTP model server over a saved model\n\
       transform  offline projection through a saved model\n\
       worker     cluster worker process serving a shard directory\n\
       fit        RandomizedCCA on a worker cluster (rcca::cluster)\n\
       cluster-ckpt inspect a driver checkpoint: fingerprint, passes, CRC status\n\
       ingest     append validated shards under a versioned snapshot manifest\n\
       daemon     drift-monitoring warm-refit loop (rcca::lifecycle)\n\
       manifest   print + validate a store's snapshot manifest\n\
       shard-info   inspect a shard file: header, nnz, CRC status\n\
       bench-check  gate a BENCH_*.json trajectory against its baseline\n\
       trace      pretty-print a JSONL span trace written by --trace\n\
     \n\
     Run `repro <subcommand> --help` for flags.\n"
        .to_string()
}

fn scale_flags(spec: Spec) -> Spec {
    spec.opt("n", "30000", "sentence pairs")
        .opt("dims", "4096", "hashed feature dimension per view")
        .opt("topics", "96", "latent topics in the generator")
        .opt("k", "60", "embedding dimension k")
        .opt("seed", "246813579", "corpus seed")
        .switch("tiny", "use the tiny CI scale (overrides n/dims/topics/k)")
}

fn scale_from(args: &Args) -> anyhow::Result<Scale> {
    if args.bool("tiny")? {
        return Ok(Scale::tiny());
    }
    if args.get("workload") == Some("generalization") {
        return Ok(Scale::generalization());
    }
    Ok(Scale {
        n: args.usize("n")?,
        dims: args.usize("dims")?,
        topics: args.usize("topics")?,
        k: args.usize("k")?,
        seed: args.u64("seed")?,
        ..Default::default()
    })
}

fn emit(report: &Report, dir: &str) -> anyhow::Result<()> {
    print!("{}", report.render());
    let path = report.write_json(dir)?;
    println!("json: {path}\n");
    Ok(())
}

fn run(argv: Vec<String>) -> anyhow::Result<()> {
    let Some((cmd, rest)) = argv.split_first() else {
        print!("{}", usage());
        return Ok(());
    };
    let rest = rest.to_vec();
    match cmd.as_str() {
        "gen" => cmd_gen(rest),
        "rcca" => cmd_rcca(rest),
        "horst" => cmd_horst(rest),
        "spectrum" => cmd_spectrum(rest),
        "fig2a" => cmd_fig2a(rest),
        "table2b" => cmd_table2b(rest),
        "nu-sweep" => cmd_nu(rest),
        "serve" => cmd_serve(rest),
        "transform" => cmd_transform(rest),
        "worker" => cmd_worker(rest),
        "fit" => cmd_fit(rest),
        "cluster-ckpt" => cmd_cluster_ckpt(rest),
        "ingest" => cmd_ingest(rest),
        "daemon" => cmd_daemon(rest),
        "manifest" => cmd_manifest(rest),
        "shard-info" => cmd_shard_info(rest),
        "bench-check" => cmd_bench_check(rest),
        "trace" => cmd_trace(rest),
        "--help" | "-h" | "help" => {
            print!("{}", usage());
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}'\n\n{}", usage()),
    }
}

fn parse(spec: Spec, argv: &[String]) -> anyhow::Result<Args> {
    spec.parse(argv).map_err(|e| anyhow::anyhow!("{e}"))
}

fn cmd_gen(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = scale_flags(Spec::new("gen", "generate + shard a SynthParl workload"))
        .opt("out", "work/shards", "output shard directory")
        .opt("rows-per-shard", "1024", "rows per shard file");
    let args = parse(spec, &argv)?;
    let scale = scale_from(&args)?;
    let t = Timer::start();
    let w = Workload::generate(scale);
    let mut writer = rcca::data::shards::ShardWriter::create(
        Path::new(args.str("out")),
        args.usize("rows-per-shard")?,
    )?;
    writer.write_dataset(&w.train.a, &w.train.b)?;
    println!(
        "generated n={} (train {} / test {}), d={}, nnz a={} b={} in {:.1}s -> {}",
        w.scale.n,
        w.train.rows(),
        w.test.rows(),
        w.scale.dims,
        w.train.a.nnz(),
        w.train.b.nnz(),
        t.secs(),
        args.str("out")
    );
    Ok(())
}

fn common_run_flags(spec: Spec) -> Spec {
    scale_flags(spec)
        .opt(
            "engine",
            "inmemory",
            "compute path: inmemory|native|pjrt, or a full spec like \
             'native:work/shards?workers=2&chunk=256', \
             'native:work/shards?cache=false&prefetch=4&io-threads=2' \
             (out-of-core streaming), or \
             'cluster:127.0.0.1:9301,127.0.0.1:9302' (a spec is authoritative \
             over pre-sharded data: --workers/--chunk-rows/--workdir are ignored)",
        )
        .opt("workers", "2", "coordinator worker threads")
        .opt("chunk-rows", "256", "rows per engine chunk")
        .opt("workdir", "work", "scratch dir for shards")
        .opt("report-dir", "reports", "where JSON twins are written")
        .opt("nu", "0.01", "scale-free regularization nu")
}

/// Engine selection through the api layer: a bare backend name builds (and
/// shards, if needed) the generated workload using the --workers/--chunk-rows
/// flags; a spec with ':' points at pre-sharded data on disk and carries its
/// own ?options, so those flags are ignored.
fn engine_from_args(args: &Args, w: &Workload) -> anyhow::Result<Engine> {
    let spec = args.str("engine");
    if spec.contains(':') {
        let engine = Engine::from_spec(spec)?;
        // λ resolution and the train/test metrics still come from the
        // generated workload, so the on-disk data must be the same shape;
        // anything else would score the fit against an unrelated dataset.
        let (n, da, db) = engine.shape();
        anyhow::ensure!(
            (n, da, db) == (w.train.rows(), w.scale.dims, w.scale.dims),
            "engine spec '{spec}' points at data shaped (n={n}, da={da}, db={db}), but the \
             workload generated from the scale flags is (n={}, d={}). Regularization and \
             train/test objectives are computed from the generated workload, so the shards \
             must come from the same gen flags (n/dims/seed).",
            w.train.rows(),
            w.scale.dims
        );
        return Ok(engine);
    }
    let backend: Backend = spec.parse()?;
    Ok(Engine::for_workload(
        w,
        backend,
        Path::new(args.str("workdir")),
        args.usize("workers")?,
        args.usize("chunk-rows")?,
    )?)
}

fn cmd_rcca(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = common_run_flags(Spec::new("rcca", "run RandomizedCCA (Algorithm 1)"))
        .opt("p", "240", "oversampling")
        .opt("q", "1", "power iterations")
        .opt("save", "", "write the fitted model JSON to this path")
        .opt("trace", "", "write a JSONL span trace of the fit to this path");
    let args = parse(spec, &argv)?;
    let scale = scale_from(&args)?;
    let k = scale.k;
    let w = Workload::generate(scale);
    let (la, lb) = w.lambdas(args.f64("nu")?);
    let mut engine = engine_from_args(&args, &w)?;
    let trace_path = args.str("trace");
    if !trace_path.is_empty() {
        telemetry::install_default();
    }
    let t = Timer::start();
    let model = Cca::builder()
        .k(k)
        .oversample(args.usize("p")?)
        .power_iters(args.usize("q")?)
        .lambda(la, lb)
        .seed(w.scale.seed ^ 0xacca)
        .fit(&mut engine)?;
    let fit_secs = t.secs();
    // Export before the evaluation passes below: objective() drives extra
    // engine passes, and the trace contract is "the fit alone" — exactly
    // q+1 `pass` spans for the randomized solver.
    export_trace(trace_path)?;
    let train = model.objective(&mut engine);
    let test = model.objective(&mut w.test_engine());
    let feas = model.feasibility(&mut engine);

    let mut r = Report::new("RandomizedCCA run", &["metric", "value"]);
    r.row(&["engine".into(), args.str("engine").into()]);
    r.row(&["k / p / q".into(), format!("{k} / {} / {}", args.str("p"), args.str("q"))]);
    r.row(&["fit time (s)".into(), format!("{fit_secs:.2}")]);
    r.row(&["data passes (fit)".into(), model.passes().to_string()]);
    r.row(&["train objective".into(), format!("{:.4}", train.sum_corr)]);
    r.row(&["test objective".into(), format!("{:.4}", test.sum_corr)]);
    r.row(&["feasibility cov err".into(), format!("{:.2e}", feas.cov_a_err.max(feas.cov_b_err))]);
    r.row(&["feasibility offdiag".into(), format!("{:.2e}", feas.cross_offdiag)]);
    let save = args.str("save");
    if !save.is_empty() {
        // Cold fits record which manifest snapshot they saw so the refit
        // daemon (and `/v1/model`) can tie the served model to its data.
        let model = match spec_store_dir(args.str("engine"))
            .and_then(|dir| Manifest::load(Path::new(dir)).ok())
        {
            Some(m) => model.with_provenance(Provenance {
                snapshot_version: m.version,
                shards: m.shards.len(),
                rows: m.rows(),
                data_hash: m.data_hash(),
                trigger: "cold".to_string(),
            }),
            None => model,
        };
        model.save(Path::new(save))?;
        r.row(&["model saved to".into(), save.into()]);
    }
    emit(&r, args.str("report-dir"))
}

/// Shard-store directory named by an engine spec, if any: the part of an
/// `inmemory:DIR` / `native:DIR?opts` spec before the option query. Cluster
/// specs name worker addresses, not a local store.
fn spec_store_dir(spec: &str) -> Option<&str> {
    let rest = spec
        .strip_prefix("inmemory:")
        .or_else(|| spec.strip_prefix("native:"))?;
    rest.split('?').next()
}

/// Drain the flight recorder to `path` and switch tracing back off. A
/// no-op for the empty path, so callers can pass `--trace` through
/// unconditionally.
fn export_trace(path: &str) -> anyhow::Result<()> {
    if path.is_empty() {
        return Ok(());
    }
    let (spans, dropped) = telemetry::export_jsonl(Path::new(path))?;
    telemetry::disable();
    println!("trace: {spans} spans ({dropped} dropped) -> {path}");
    Ok(())
}

/// Serve `GET /metrics` (JSON, or Prometheus text with `?format=prom`)
/// from a background thread for the life of the process — just enough
/// HTTP for scrapers and the CI smokes, without the full `rcca::serve`
/// model-server stack. Returns the bound address (so `--metrics-listen
/// 127.0.0.1:0` works in tests).
fn serve_metrics(
    listen: &str,
    registry: Arc<telemetry::MetricsRegistry>,
) -> anyhow::Result<SocketAddr> {
    let listener = std::net::TcpListener::bind(listen)
        .map_err(|e| anyhow::anyhow!("--metrics-listen {listen}: {e}"))?;
    let addr = listener.local_addr()?;
    println!("metrics listening at {addr}");
    std::thread::Builder::new()
        .name("fit-metrics".into())
        .spawn(move || {
            for stream in listener.incoming() {
                let Ok(mut stream) = stream else { continue };
                let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
                let mut line = String::new();
                {
                    use std::io::BufRead;
                    let mut reader = std::io::BufReader::new(&mut stream);
                    if reader.read_line(&mut line).is_err() {
                        continue;
                    }
                }
                let target = line.split_whitespace().nth(1).unwrap_or("/");
                let (status, ctype, body) = if target.starts_with("/metrics") {
                    if target.contains("format=prom") {
                        ("200 OK", "text/plain; version=0.0.4", registry.render_prom())
                    } else {
                        ("200 OK", "application/json", registry.render_json().to_string())
                    }
                } else {
                    ("404 Not Found", "text/plain", "not found\n".to_string())
                };
                use std::io::Write;
                let _ = write!(
                    stream,
                    "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
                    body.len()
                );
            }
        })?;
    Ok(addr)
}

fn cmd_horst(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = common_run_flags(Spec::new("horst", "run the Horst-iteration baseline"))
        .opt("passes", "120", "data-pass budget")
        .opt("init", "none", "initializer: none|rcca")
        .opt("init-p", "120", "rcca initializer oversampling")
        .opt("init-q", "1", "rcca initializer power iterations");
    let args = parse(spec, &argv)?;
    let scale = scale_from(&args)?;
    let k = scale.k;
    let w = Workload::generate(scale);
    let (la, lb) = w.lambdas(args.f64("nu")?);
    let mut engine = engine_from_args(&args, &w)?;
    let warm_start = match args.str("init") {
        "rcca" => true,
        "none" => false,
        other => anyhow::bail!("unknown --init '{other}'"),
    };
    let t = Timer::start();
    let model = Cca::builder()
        .k(k)
        .oversample(args.usize("init-p")?)
        .power_iters(args.usize("init-q")?)
        .lambda(la, lb)
        .solver(Solver::Horst { warm_start })
        .pass_budget(args.usize("passes")?)
        .seed(0x1217)
        .horst_seed(0x4057)
        .fit(&mut engine)?;
    let secs = t.secs();
    let train = model.objective(&mut engine);
    let test = model.objective(&mut w.test_engine());
    let iterations = model.trace.as_ref().map(|t| t.len()).unwrap_or(0);
    let mut r = Report::new("Horst run", &["metric", "value"]);
    r.row(&["init".into(), args.str("init").into()]);
    r.row(&["time (s)".into(), format!("{secs:.2}")]);
    r.row(&["passes".into(), model.passes().to_string()]);
    r.row(&["iterations".into(), iterations.to_string()]);
    r.row(&["train objective".into(), format!("{:.4}", train.sum_corr)]);
    r.row(&["test objective".into(), format!("{:.4}", test.sum_corr)]);
    emit(&r, args.str("report-dir"))
}

fn cmd_spectrum(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = common_run_flags(Spec::new("spectrum", "Figure 1: spectrum of (1/n) A'B"))
        .opt("top", "512", "singular values to estimate")
        .opt("oversample", "64", "sketch oversampling");
    let args = parse(spec, &argv)?;
    let scale = scale_from(&args)?;
    let w = Workload::generate(scale);
    let mut engine = engine_from_args(&args, &w)?;
    let res = experiments::e1_spectrum::run(
        &mut engine,
        &w,
        args.usize("top")?,
        args.usize("oversample")?,
        w.scale.seed ^ 0x57ec,
    );
    emit(
        &experiments::e1_spectrum::report(&res, (args.usize("top")? / 32).max(1)),
        args.str("report-dir"),
    )
}

fn cmd_fig2a(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = scale_flags(Spec::new("fig2a", "Figure 2a: objective vs (q, p)"))
        .opt("qs", "0,1,2,3", "q values")
        .opt("ps", "10,40,100,240", "p values")
        .opt("horst-passes", "120", "Horst reference budget")
        .opt("report-dir", "reports", "where JSON twins are written");
    let args = parse(spec, &argv)?;
    let w = Workload::generate(scale_from(&args)?);
    let res = experiments::e2_sweep::run(
        &w,
        &args.usize_list("qs")?,
        &args.usize_list("ps")?,
        args.usize("horst-passes")?,
    )?;
    emit(
        &experiments::e2_sweep::report(&res, w.scale.k),
        args.str("report-dir"),
    )
}

fn cmd_table2b(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = scale_flags(Spec::new("table2b", "Table 2b: times + train/test objectives"))
        .opt("workload", "generalization", "workload preset: generalization|standard")
        .opt("horst-passes", "120", "Horst budget")
        .opt("report-dir", "reports", "where JSON twins are written");
    let args = parse(spec, &argv)?;
    let w = Workload::generate(scale_from(&args)?);
    let mut cfg = experiments::e3_table::TableConfig::scaled(&w);
    cfg.horst_budget = args.usize("horst-passes")?;
    let res = experiments::e3_table::run(&w, &cfg)?;
    emit(&experiments::e3_table::report(&res), args.str("report-dir"))
}

fn cmd_serve(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = Spec::new("serve", "serve a saved model over HTTP (rcca::serve)")
        .req("model", "path to a saved rcca-model-v1 document")
        .opt("addr", "127.0.0.1:8077", "listen address (port 0 = ephemeral)")
        .opt(
            "threads",
            "8",
            "connection-handler threads (each open keep-alive connection pins one)",
        )
        .opt("queue", "128", "pending-connection bound; 429 + Retry-After beyond it")
        .opt("max-batch-rows", "256", "row budget per fused transform batch")
        .opt("read-timeout-secs", "30", "idle keep-alive read timeout (s)")
        .opt(
            "default-deadline-ms",
            "10000",
            "time budget for requests without an x-rcca-deadline-ms header",
        )
        .opt(
            "max-deadline-ms",
            "60000",
            "ceiling on any request's budget (also bounds the header read)",
        )
        .opt(
            "transform-inflight",
            "0",
            "concurrent /v1/transform cap before 429 shedding (0 = threads-2)",
        )
        .opt(
            "breaker-threshold",
            "3",
            "consecutive batcher failures that open the circuit breaker",
        )
        .opt(
            "breaker-cooldown-ms",
            "1000",
            "how long the breaker stays open before a half-open probe",
        )
        .opt(
            "chaos",
            "",
            "deterministic serve fault plan, e.g. \
             'batcher-stall=2x400,torn-write=1,worker-panic=1,corrupt-reload=1,batcher-fail=3' \
             (counts are finite budgets: the server provably recovers)",
        );
    let args = parse(spec, &argv)?;
    let threads = args.usize("threads")?;
    let queue = args.usize("queue")?;
    let max_batch_rows = args.usize("max-batch-rows")?;
    anyhow::ensure!(
        threads > 0 && queue > 0 && max_batch_rows > 0,
        "--threads/--queue/--max-batch-rows must be positive"
    );
    let chaos_spec = args.str("chaos");
    let chaos = if chaos_spec.is_empty() {
        rcca::chaos::ServePlan::none()
    } else {
        rcca::chaos::ServePlan::parse(chaos_spec).map_err(|e| anyhow::anyhow!("--chaos: {e}"))?
    };
    let cfg = ServerConfig {
        threads,
        queue_capacity: queue,
        max_batch_rows,
        read_timeout: Duration::from_secs(args.u64("read-timeout-secs")?.max(1)),
        default_deadline: Duration::from_millis(args.u64("default-deadline-ms")?.max(1)),
        max_deadline: Duration::from_millis(args.u64("max-deadline-ms")?.max(1)),
        transform_inflight: args.usize("transform-inflight")?,
        breaker_threshold: args.u64("breaker-threshold")?.max(1) as u32,
        breaker_cooldown: Duration::from_millis(args.u64("breaker-cooldown-ms")?),
        chaos,
        ..Default::default()
    };
    let server = Server::bind(Path::new(args.str("model")), args.str("addr"), cfg)?;
    // Stdout is line-buffered, so the smoke tooling can read the bound
    // address even when output is redirected.
    println!(
        "serving {} at http://{}",
        args.str("model"),
        server.local_addr()
    );
    println!(
        "endpoints: GET /healthz | GET /v1/model | GET /metrics[?format=prom] | \
         POST /v1/transform | POST /admin/reload"
    );
    if !chaos_spec.is_empty() {
        println!("chaos plan active: {chaos_spec}");
    }
    server.run();
    Ok(())
}

fn cmd_transform(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = scale_flags(Spec::new(
        "transform",
        "project a dataset through a saved model (offline twin of serve)",
    ))
    .req("model", "path to a saved rcca-model-v1 document")
    .opt("view", "a", "which view to project: a|b")
    .opt(
        "shards",
        "",
        "shard directory to project; empty = the generated test split from the scale flags",
    )
    .opt("out", "projections.json", "output JSON path");
    let args = parse(spec, &argv)?;
    let model = FittedModel::load(Path::new(args.str("model")))?;
    let view = View::parse(args.str("view"))?;
    let shards = args.str("shards");
    let (csr, source) = if shards.is_empty() {
        let w = Workload::generate(scale_from(&args)?);
        let csr = match view {
            View::A => w.test.a,
            View::B => w.test.b,
        };
        (csr, "generated test split".to_string())
    } else {
        let chunk = rcca::data::shards::ShardStore::open(Path::new(shards))
            .map_err(|e| anyhow::anyhow!("open shards: {e}"))?
            .load_all()
            .map_err(|e| anyhow::anyhow!("load shards: {e}"))?;
        let csr = match view {
            View::A => chunk.a,
            View::B => chunk.b,
        };
        (csr, shards.to_string())
    };
    let t = Timer::start();
    let proj = view.transform(&model, &csr)?;
    let doc = proto::projection_document(view, &proj, None);
    let out = Path::new(args.str("out"));
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(out, doc.to_string_pretty())?;
    println!(
        "projected {} rows (view {}) from {} through {} in {:.2}s -> {}",
        proj.rows,
        view.as_str(),
        source,
        args.str("model"),
        t.secs(),
        out.display()
    );
    Ok(())
}

/// Parse a `--chaos` fault plan, treating the empty string as "no faults"
/// so the flag can be threaded through unconditionally.
fn parse_chaos(spec: &str) -> anyhow::Result<ChaosPlan> {
    if spec.is_empty() {
        return Ok(ChaosPlan::none());
    }
    ChaosPlan::parse(spec).map_err(|e| anyhow::anyhow!("--chaos: {e}"))
}

/// `repro worker` — one cluster worker process (see `rcca::cluster`). It
/// serves pass tasks over its local shard directory to a driver
/// (`repro fit --cluster ...`) until killed.
fn cmd_worker(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = Spec::new("worker", "cluster worker: serve shard passes to a driver")
        .req("shards", "shard directory to serve (written by `repro gen`)")
        .opt("listen", "127.0.0.1:0", "listen address (port 0 = ephemeral)")
        .switch("no-cache", "re-read shards from disk on every pass (out-of-core regime)")
        .opt(
            "exit-after-partials",
            "0",
            "fault injection: crash the process after sending N partials (0 = off; \
             used by the chaos tests and CI to exercise driver recovery)",
        )
        .opt(
            "mirror-from",
            "",
            "peer worker (host:port) to pull missing replica shards from when an \
             assignment names shards this store does not hold",
        )
        .opt(
            "join",
            "",
            "driver --listen address (host:port) to dial and join a running job, \
             in addition to accepting inbound drivers",
        )
        .opt(
            "chaos",
            "",
            "deterministic fault plan, e.g. 'kill-at-pass=1' or 'drop-heartbeats=1' \
             (see `repro fit --help` for the grammar)",
        );
    let args = parse(spec, &argv)?;
    let opt = |s: &str| (!s.is_empty()).then(|| s.to_string());
    let config = WorkerConfig {
        cache_shards: !args.bool("no-cache")?,
        exit_after_partials: args.u64("exit-after-partials")?,
        mirror_from: opt(args.str("mirror-from")),
        join: opt(args.str("join")),
        chaos: parse_chaos(args.str("chaos"))?,
        ..Default::default()
    };
    let worker = Worker::bind(Path::new(args.str("shards")), args.str("listen"), config)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let store = worker.store();
    // Stdout is line-buffered: launchers (tests, CI, quickstart scripts)
    // scrape the bound address from this line.
    println!(
        "worker listening at {} serving {} shards ({} rows, d={}x{})",
        worker.local_addr(),
        store.shards,
        store.rows,
        store.dims_a,
        store.dims_b
    );
    worker.run()
}

/// `repro fit` — RandomizedCCA on a worker cluster: the distributed twin
/// of `repro rcca`. The workers' dataset must match the scale flags (λ
/// resolution and train/test objectives come from the generated workload).
fn cmd_fit(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = scale_flags(Spec::new("fit", "run RandomizedCCA on a worker cluster"))
        .req("cluster", "comma-separated worker addresses (host:port,host:port)")
        .opt("p", "240", "oversampling")
        .opt("q", "1", "power iterations")
        .opt("nu", "0.01", "scale-free regularization nu")
        .opt("chunk-rows", "256", "rows per engine chunk on every worker")
        .opt("max-retries", "2", "per-shard retry budget")
        .opt(
            "prefetch-depth",
            "2",
            "out-of-core workers (--no-cache): shards each worker reads ahead of compute \
             (0 = blocking loads; perf-only, results are bitwise identical)",
        )
        .opt("io-threads", "1", "out-of-core workers: reader threads feeding the prefetch queue")
        .opt("heartbeat-timeout-secs", "10", "silence after which a worker is declared dead")
        .opt("connect-attempts", "4", "bounded-backoff dial attempts per worker address")
        .opt(
            "replication",
            "1",
            "shard replica factor R: with R>=2 (and workers able to --mirror-from a \
             holder), a death re-dispatches to a replica instead of aborting",
        )
        .opt(
            "checkpoint",
            "",
            "persist the pass ledger + committed reductions here after every pass \
             (CRC-framed, atomic rename)",
        )
        .opt(
            "resume",
            "",
            "resume from a checkpoint written by --checkpoint: completed passes \
             replay bitwise without new network rounds",
        )
        .opt(
            "listen",
            "",
            "accept workers joining mid-job (`repro worker --join`) on this address",
        )
        .opt(
            "chaos",
            "",
            "driver-side fault plan: die-after-pass=N | torn-checkpoint \
             (comma-separated; used by the chaos tests and CI)",
        )
        .opt("report-dir", "reports", "where JSON twins are written")
        .opt("save", "", "write the fitted model JSON to this path")
        .opt(
            "trace",
            "",
            "write ONE merged cross-process JSONL span trace of the fit (driver rounds \
             with every worker's round/shard_task spans parented under them)",
        )
        .opt(
            "straggler-factor",
            "2.0",
            "flag a worker as a straggler when its round latency exceeds the fleet \
             median by this factor (ledger event + rcca_cluster_stragglers gauge)",
        )
        .opt(
            "metrics-listen",
            "",
            "serve GET /metrics for the cluster ledger on this address during the fit \
             (JSON; append ?format=prom for Prometheus text)",
        )
        .opt(
            "metrics-linger-secs",
            "0",
            "keep the --metrics-listen endpoint up this long after the fit report, so \
             external scrapers (CI smokes) can read the final gauges",
        );
    let args = parse(spec, &argv)?;
    let scale = scale_from(&args)?;
    let k = scale.k;
    let w = Workload::generate(scale);
    let (la, lb) = w.lambdas(args.f64("nu")?);
    let addrs = rcca::cluster::parse_addrs(args.str("cluster"));
    let path_opt = |s: &str| (!s.is_empty()).then(|| PathBuf::from(s));
    let config = ClusterConfig {
        chunk_rows: args.usize("chunk-rows")?,
        max_retries: args.usize("max-retries")?,
        prefetch_depth: args.usize("prefetch-depth")?,
        io_threads: args.usize("io-threads")?,
        heartbeat_timeout: Duration::from_secs(args.u64("heartbeat-timeout-secs")?.max(1)),
        connect_attempts: args.usize("connect-attempts")?.max(1),
        replication: args.usize("replication")?.max(1),
        checkpoint: path_opt(args.str("checkpoint")),
        resume: path_opt(args.str("resume")),
        listen: (!args.str("listen").is_empty()).then(|| args.str("listen").to_string()),
        chaos: parse_chaos(args.str("chaos"))?,
        straggler_factor: args.f64("straggler-factor")?,
        ..Default::default()
    };
    let mut engine = Engine::cluster(&addrs, config)?;
    let (n, da, db) = engine.shape();
    anyhow::ensure!(
        (n, da, db) == (w.train.rows(), w.scale.dims, w.scale.dims),
        "the cluster serves data shaped (n={n}, da={da}, db={db}), but the workload generated \
         from the scale flags is (n={}, d={}). Point the workers at shards written by \
         `repro gen` with the same n/dims/seed flags.",
        w.train.rows(),
        w.scale.dims
    );
    let trace_path = args.str("trace");
    if !trace_path.is_empty() {
        telemetry::install_default();
    }
    let metrics_listen = args.str("metrics-listen");
    let metrics_addr = if metrics_listen.is_empty() {
        None
    } else {
        let registry = Arc::new(telemetry::MetricsRegistry::new());
        if let Some(ledger) = engine.cluster_ledger_arc() {
            registry.register("cluster", ledger);
        }
        Some(serve_metrics(metrics_listen, registry)?)
    };
    let t = Timer::start();
    let model = Cca::builder()
        .k(k)
        .oversample(args.usize("p")?)
        .power_iters(args.usize("q")?)
        .lambda(la, lb)
        .seed(w.scale.seed ^ 0xacca)
        .fit(&mut engine)?;
    let fit_secs = t.secs();
    // Evaluation drives more cluster rounds; keep the trace fit-only (one
    // `round` span per fit pass), mirroring the ledger snapshot below.
    if !trace_path.is_empty() {
        match engine.export_merged_trace(Path::new(trace_path)) {
            Some(res) => {
                let (spans, dropped) = res?;
                telemetry::disable();
                println!("trace: {spans} merged spans ({dropped} dropped) -> {trace_path}");
            }
            // Non-cluster engines have no remote shards to merge; fall back
            // to the plain driver-local export.
            None => export_trace(trace_path)?,
        }
    }
    // The claim under test: every fit pass was exactly one network round.
    // The rounds figure comes from the DRIVER's ledger (its RunPass round
    // counter), not from the model's pass ledger, so the two rows below
    // can disagree if a pass ever costs more than one round. Snapshot
    // before the evaluation passes so the table reflects the fit alone.
    let fit_ledger = engine.cluster_ledger();
    let fit_rounds = fit_ledger
        .as_ref()
        .and_then(|l| l.get("rounds"))
        .and_then(|v| v.as_usize())
        .unwrap_or(0);
    let train = model.objective(&mut engine);
    let test = model.objective(&mut w.test_engine());

    let mut r = Report::new("RandomizedCCA cluster fit", &["metric", "value"]);
    r.row(&["workers".into(), addrs.len().to_string()]);
    r.row(&["k / p / q".into(), format!("{k} / {} / {}", args.str("p"), args.str("q"))]);
    r.row(&["fit time (s)".into(), format!("{fit_secs:.2}")]);
    r.row(&["cluster rounds (fit)".into(), fit_rounds.to_string()]);
    let stragglers = fit_ledger
        .as_ref()
        .and_then(|l| l.get("stragglers"))
        .and_then(|v| v.as_usize())
        .unwrap_or(0);
    r.row(&["stragglers (fit)".into(), stragglers.to_string()]);
    r.row(&["data passes (fit)".into(), model.passes().to_string()]);
    r.row(&["train objective".into(), format!("{:.4}", train.sum_corr)]);
    r.row(&["test objective".into(), format!("{:.4}", test.sum_corr)]);
    if let Some(ledger) = fit_ledger {
        if let Some(workers) = ledger.get("workers").and_then(|w| w.as_arr()) {
            for entry in workers {
                let addr = entry.get("addr").and_then(|v| v.as_str()).unwrap_or("?");
                let rounds = entry.get("rounds").and_then(|v| v.as_usize()).unwrap_or(0);
                let shards = entry
                    .get("shards_completed")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(0);
                let dead = entry.get("dead").and_then(|v| v.as_bool()).unwrap_or(false);
                r.row(&[
                    format!("worker {addr}"),
                    format!(
                        "rounds={rounds} shards={shards}{}",
                        if dead { " DEAD" } else { "" }
                    ),
                ]);
            }
        }
    }
    let save = args.str("save");
    if !save.is_empty() {
        model.save(Path::new(save))?;
        r.row(&["model saved to".into(), save.into()]);
    }
    emit(&r, args.str("report-dir"))?;
    // Hold the metrics endpoint open after the report so out-of-process
    // scrapers (the CI trace smoke) can read the final straggler/event
    // gauges before the driver exits.
    let linger = args.u64("metrics-linger-secs")?;
    if let Some(addr) = metrics_addr {
        if linger > 0 {
            eprintln!("metrics: lingering {linger}s for scrapes on {addr}");
            std::thread::sleep(Duration::from_secs(linger));
        }
    }
    Ok(())
}

/// `repro cluster-ckpt <path>` — print + validate a driver checkpoint
/// written by `repro fit --checkpoint`. The distributed twin of
/// `shard-info`: it decodes the fingerprint and every pass record, and
/// exits nonzero when the file is torn or unreadable, so scripts can gate
/// a `--resume` on checkpoint integrity first.
fn cmd_cluster_ckpt(argv: Vec<String>) -> anyhow::Result<()> {
    let mut argv = argv;
    // Accept the file positionally (`repro cluster-ckpt fit.ckpt`).
    let positional = argv.first().map(|f| !f.starts_with("--")).unwrap_or(false);
    if positional {
        let file = argv.remove(0);
        argv.insert(0, format!("--file={file}"));
    }
    let spec = Spec::new("cluster-ckpt", "inspect a driver checkpoint")
        .req(
            "file",
            "checkpoint written by `repro fit --checkpoint` (positional also accepted)",
        );
    let args = parse(spec, &argv)?;
    let path = Path::new(args.str("file"));
    let ck = Checkpoint::load(path).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let fp = &ck.fingerprint;
    println!("checkpoint {}", path.display());
    println!(
        "dataset    {} shards, {} rows, d={}x{}, chunk {}",
        fp.shards, fp.rows, fp.dims_a, fp.dims_b, fp.chunk_rows
    );
    println!("passes     {}", ck.records.len());
    for rec in &ck.records {
        let outs: Vec<String> = rec
            .outputs
            .iter()
            .map(|m| format!("{}x{}", m.rows, m.cols))
            .collect();
        println!(
            "  pass {:>3}  {:<5}  r={:<4}  input crc {:08x}  outputs [{}]",
            rec.pass_index,
            rec.kind.as_str(),
            rec.r,
            rec.input_crc,
            outs.join(", ")
        );
    }
    println!("status     OK");
    Ok(())
}

/// `repro ingest` — append validated shards to a store under its snapshot
/// manifest. Opening the store bootstraps a manifest over any pre-existing
/// `repro gen` output, so this is also the migration path for old stores.
fn cmd_ingest(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = scale_flags(Spec::new(
        "ingest",
        "append validated shards under a versioned snapshot manifest",
    ))
    .req("store", "shard store directory (created or bootstrapped as needed)")
    .opt("shards", "", "comma-separated shard files to append")
    .opt("gen-rows", "0", "generate and append this many fresh SynthParl rows")
    .opt("batch", "1", "generator batch index (fresh rows, same feature space)")
    .opt("drift", "0.0", "generator topic-drift intensity in [0, 1]");
    let args = parse(spec, &argv)?;
    let store = Path::new(args.str("store"));
    let mut ing = Ingestor::open(store)?;
    for file in args.str("shards").split(',').filter(|s| !s.is_empty()) {
        let m = ing.append_shard_file(Path::new(file))?;
        println!("appended {file} -> version {}", m.version);
    }
    let gen_rows = args.usize("gen-rows")?;
    if gen_rows > 0 {
        let scale = scale_from(&args)?;
        let mut cfg = scale.corpus_config();
        cfg.n = gen_rows;
        cfg.batch = args.u64("batch")?;
        cfg.drift = args.f64("drift")?;
        let d = SynthParl::generate(cfg);
        let m = ing.append_chunk(&TwoViewChunk { a: d.a, b: d.b })?;
        println!(
            "appended {gen_rows} generated rows (batch {}, drift {}) -> version {}",
            args.str("batch"),
            args.str("drift"),
            m.version
        );
    }
    let m = ing.manifest();
    println!(
        "ingest: store {} now at version {} ({} shards, {} rows, hash {})",
        store.display(),
        m.version,
        m.shards.len(),
        m.rows(),
        m.data_hash()
    );
    Ok(())
}

/// `repro daemon` — the lifecycle loop: poll the store manifest, score
/// drift against the live model, warm-refit when triggered, hot-swap the
/// serve registry, and record each episode in the audit ledger.
fn cmd_daemon(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = Spec::new("daemon", "drift-monitoring warm-refit loop")
        .req("store", "shard store directory (must carry a manifest)")
        .req("model", "fitted model JSON path (refits rewrite it atomically)")
        .opt("reload-addr", "", "serve instance to hot-swap via POST /admin/reload")
        .opt(
            "engine",
            "inmemory",
            "refit engine over the snapshot: inmemory, native[?opts], or \
             cluster:addr,addr",
        )
        .opt("drift-threshold", "0.25", "relative correlation decay that triggers a refit")
        .opt("min-new-rows", "1", "ignore drift until this many fresh rows arrive")
        .opt("pass-budget", "24", "warm-refit data-pass budget")
        .opt("tol", "0.001", "warm-refit convergence tolerance")
        .opt("refit-every-secs", "0", "periodic refit interval (0 = drift-only)")
        .opt("poll-ms", "500", "manifest poll interval")
        .opt("audit", "", "audit ledger path (default <store>/audit.jsonl)")
        .opt("retain", "512", "audit episodes kept before compaction (0 = unbounded)")
        .opt("max-episodes", "0", "exit after this many refit episodes (0 = run forever)")
        .opt("trace", "", "write a JSONL span trace of ticks/refits on exit")
        .switch("once", "run exactly one tick and exit (errors become the exit code)");
    let args = parse(spec, &argv)?;
    let store = Path::new(args.str("store")).to_path_buf();
    let model_path = Path::new(args.str("model")).to_path_buf();
    let audit = match args.str("audit") {
        "" => store.join("audit.jsonl"),
        p => Path::new(p).to_path_buf(),
    };
    let refit_every = match args.u64("refit-every-secs")? {
        0 => None,
        s => Some(Duration::from_secs(s)),
    };
    let config = DaemonConfig {
        drift_threshold: args.f64("drift-threshold")?,
        min_new_rows: args.usize("min-new-rows")?,
        pass_budget: args.usize("pass-budget")?,
        tol: args.f64("tol")?,
        refit_every,
        engine: args.str("engine").to_string(),
        retention: Retention {
            max_records: args.usize("retain")?,
        },
    };
    let mut daemon = Daemon::new(&store, &model_path, &audit, config);
    let reload = args.str("reload-addr");
    if !reload.is_empty() {
        let addr: SocketAddr = reload
            .parse()
            .map_err(|e| anyhow::anyhow!("--reload-addr '{reload}': {e}"))?;
        daemon = daemon.with_http_reload(addr);
    }
    let once = args.bool("once")?;
    let max_episodes = args.u64("max-episodes")?;
    let poll = Duration::from_millis(args.u64("poll-ms")?);
    let trace_path = args.str("trace");
    if !trace_path.is_empty() {
        telemetry::install_default();
    }
    let mut refits = 0u64;
    let mut was_idle = false;
    loop {
        let now = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        match daemon.tick(now) {
            Ok(Tick::Idle { version }) => {
                if !was_idle {
                    println!("idle: snapshot v{version}, no fresh data");
                }
                was_idle = true;
            }
            Ok(Tick::Observed { version, score }) => {
                was_idle = false;
                println!("observed: snapshot v{version} drift={score:.4} (below trigger)");
            }
            Ok(Tick::NoOp { version }) => {
                was_idle = false;
                println!("noop: refit due but snapshot v{version} is unchanged, keeping model");
            }
            Ok(Tick::Refit(ep)) => {
                was_idle = false;
                refits += 1;
                println!(
                    "refit: trigger={} snapshot={} drift={:.4} passes={} corr {:.4} -> {:.4} \
                     generation={}",
                    ep.trigger,
                    ep.snapshot_version,
                    ep.drift_score,
                    ep.passes,
                    ep.sum_corr_before,
                    ep.sum_corr_after,
                    ep.generation
                );
            }
            Err(e) if once => {
                // Best-effort: the failing tick's spans are exactly what a
                // debugger wants, but the tick error stays the exit cause.
                let _ = export_trace(trace_path);
                return Err(e.into());
            }
            Err(e) => {
                was_idle = false;
                eprintln!("daemon: {e}");
            }
        }
        if once || (max_episodes > 0 && refits >= max_episodes) {
            export_trace(trace_path)?;
            return Ok(());
        }
        std::thread::sleep(poll);
    }
}

/// `repro manifest <dir>` — print a store's snapshot manifest and verify
/// every shard it pins (length, CRC, decode, shape). Exits nonzero if the
/// manifest is unreadable or any shard fails validation, so scripts can
/// gate on store integrity the way `shard-info` gates on one file.
fn cmd_manifest(argv: Vec<String>) -> anyhow::Result<()> {
    let mut argv = argv;
    // Accept the directory positionally (`repro manifest work/shards`).
    let positional = argv.first().map(|f| !f.starts_with("--")).unwrap_or(false);
    if positional {
        let dir = argv.remove(0);
        argv.insert(0, format!("--dir={dir}"));
    }
    let spec = Spec::new("manifest", "print + validate a store's snapshot manifest")
        .req("dir", "shard store directory (positional also accepted)");
    let args = parse(spec, &argv)?;
    let dir = Path::new(args.str("dir"));
    let m = Manifest::load(dir)?;
    println!("store      {}", dir.display());
    println!("version    {}", m.version);
    println!("shards     {}", m.shards.len());
    println!("rows       {}", m.rows());
    println!("dims       {} x {}", m.dims_a, m.dims_b);
    println!("data hash  {}", m.data_hash());
    let checks = m.verify(dir);
    let mut corrupt = 0usize;
    for c in &checks {
        match &c.error {
            None => println!("  {}  {} rows  OK", c.file, c.rows),
            Some(e) => {
                corrupt += 1;
                println!("  {}  {} rows  CORRUPT: {e}", c.file, c.rows);
            }
        }
    }
    if corrupt > 0 {
        anyhow::bail!("{corrupt} of {} shards fail validation", checks.len());
    }
    println!("status     OK");
    Ok(())
}

/// `repro shard-info <file>` — print a shard file's header, nnz counts,
/// and CRC status. The tool for debugging worker-side load failures: it
/// keeps reporting even when the payload is corrupt, and exits nonzero so
/// scripts can gate on integrity.
fn cmd_shard_info(argv: Vec<String>) -> anyhow::Result<()> {
    let mut argv = argv;
    // Accept the file as a positional argument (`repro shard-info x.bin`)
    // or as `--file x.bin`.
    let positional = argv.first().map(|f| !f.starts_with("--")).unwrap_or(false);
    if positional {
        let file = argv.remove(0);
        argv.insert(0, format!("--file={file}"));
    }
    let spec = Spec::new("shard-info", "inspect a shard file: header, nnz, CRC status")
        .req("file", "path to a shard-NNNNN.bin file (positional also accepted)");
    let args = parse(spec, &argv)?;
    let path = Path::new(args.str("file"));
    let bytes =
        std::fs::read(path).map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let info = rcca::data::shards::inspect_shard(&bytes)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    println!("shard      {}", path.display());
    println!("bytes      {}", info.bytes);
    println!("version    {}", info.version);
    println!("rows       {}", info.rows);
    println!("dims       {} x {}", info.dims_a, info.dims_b);
    let nnz = |v: Option<u64>| v.map_or("unreadable".to_string(), |n| n.to_string());
    println!("nnz a      {}", nnz(info.nnz_a));
    println!("nnz b      {}", nnz(info.nnz_b));
    println!(
        "crc        stored {:08x} / computed {:08x} ({})",
        info.crc_stored,
        info.crc_computed,
        if info.crc_ok() { "OK" } else { "MISMATCH" }
    );
    match &info.error {
        None => {
            println!("status     OK");
            Ok(())
        }
        Some(e) => {
            println!("status     CORRUPT: {e}");
            anyhow::bail!("shard fails validation: {e}")
        }
    }
}

/// Gate a freshly measured `BENCH_*.json` trajectory against the
/// checked-in baseline snapshot: any section whose p50 regressed by more
/// than `--max-regress` fails the command (CI's bench smoke step). A
/// baseline marked `"provisional": true` — or sections present on only one
/// side — records without failing, so the gate engages as soon as a real
/// snapshot is committed (produce one with `--update` on the target
/// machine).
fn cmd_bench_check(argv: Vec<String>) -> anyhow::Result<()> {
    use rcca::util::json::Json;
    use std::collections::BTreeMap;
    let spec = Spec::new(
        "bench-check",
        "compare a bench trajectory against the checked-in baseline",
    )
    .opt("current", "BENCH_micro.json", "freshly measured trajectory")
    .opt("baseline", "BENCH_micro.baseline.json", "checked-in baseline snapshot")
    .opt("max-regress", "0.25", "maximum tolerated p50 regression (fraction, 0.25 = +25%)")
    .opt(
        "gates",
        "",
        "within-run ratio gates 'fast/base>=ratio', comma-separated — compares two sections \
         of the SAME run, so the check is machine-independent (the baseline comparison is not)",
    )
    .switch("update", "rewrite the baseline from the current trajectory");
    let args = parse(spec, &argv)?;
    let read = |path: &str| -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        rcca::util::json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
    };
    let cur_path = args.str("current");
    let base_path = args.str("baseline");
    let cur = read(cur_path)?;
    let sections = |doc: &Json, path: &str| -> anyhow::Result<BTreeMap<String, f64>> {
        let Some(Json::Obj(map)) = doc.get("sections") else {
            anyhow::bail!("{path}: missing 'sections' object");
        };
        Ok(map
            .iter()
            .filter_map(|(name, entry)| {
                entry
                    .get("p50")
                    .and_then(Json::as_f64)
                    .map(|p50| (name.clone(), p50))
            })
            .collect())
    };
    let cur_s = sections(&cur, cur_path)?;

    if args.bool("update")? {
        std::fs::write(base_path, cur.to_string_pretty())?;
        println!(
            "baseline updated: {base_path} <- {cur_path} ({} sections)",
            cur_s.len()
        );
        return Ok(());
    }

    // Within-run ratio gates: p50(base)/p50(fast) from one trajectory.
    let mut gate_failures = Vec::new();
    for g in args.str("gates").split(',').filter(|s| !s.is_empty()) {
        let bad = || anyhow::anyhow!("bad gate '{g}' (want fast/base>=ratio)");
        let (pair, ratio) = g.split_once(">=").ok_or_else(bad)?;
        let (fast, base) = pair.split_once('/').ok_or_else(bad)?;
        let min: f64 = ratio.trim().parse().map_err(|_| bad())?;
        let (fast, base) = (fast.trim(), base.trim());
        let (Some(f), Some(b)) = (cur_s.get(fast), cur_s.get(base)) else {
            anyhow::bail!("gate '{g}': section missing from {cur_path}");
        };
        let speedup = b / f;
        let ok = speedup >= min;
        println!(
            "  gate {fast} vs {base}: {speedup:.2}x (need >= {min}) {}",
            if ok { "ok" } else { "FAIL" }
        );
        if !ok {
            gate_failures.push(format!("{g} (got {speedup:.2}x)"));
        }
    }
    anyhow::ensure!(
        gate_failures.is_empty(),
        "within-run gates failed: {}",
        gate_failures.join(", ")
    );

    let base = read(base_path)?;
    let max_regress = args.f64("max-regress")?;
    if base.get("provisional").and_then(Json::as_bool).unwrap_or(false) {
        println!(
            "baseline {base_path} is provisional (no measured snapshot yet) — \
             recording only. Refresh it with `repro bench-check --update` on \
             the machine class that runs this check and commit the result to \
             arm the absolute gate (the --gates ratios are always armed)."
        );
        return Ok(());
    }
    let base_s = sections(&base, base_path)?;
    let mut regressions = Vec::new();
    let mut compared = 0usize;
    for (name, base_p50) in &base_s {
        let Some(cur_p50) = cur_s.get(name) else {
            println!("  (skip: '{name}' only in baseline)");
            continue;
        };
        compared += 1;
        let delta = cur_p50 / base_p50 - 1.0;
        let flag = if delta > max_regress { " <-- REGRESSION" } else { "" };
        println!(
            "  {name:<40} base p50 {base_p50:.3e}s  cur {cur_p50:.3e}s  {:+.1}%{flag}",
            delta * 100.0
        );
        if delta > max_regress {
            regressions.push((name.clone(), delta));
        }
    }
    for name in cur_s.keys() {
        if !base_s.contains_key(name) {
            println!("  (new: '{name}' not in baseline yet)");
        }
    }
    anyhow::ensure!(
        regressions.is_empty(),
        "{} of {compared} sections regressed past {:.0}%: {}",
        regressions.len(),
        max_regress * 100.0,
        regressions
            .iter()
            .map(|(n, d)| format!("{n} (+{:.0}%)", d * 100.0))
            .collect::<Vec<_>>()
            .join(", ")
    );
    println!("bench-check: {compared} sections within {:.0}%", max_regress * 100.0);
    Ok(())
}

/// `repro trace <file>` — pretty-print or analyze a JSONL span trace
/// written by the `--trace` flag on `rcca`/`fit`/`daemon`. Default: an
/// indented span tree with wall + thread-CPU timings, optionally filtered
/// by span name and truncated to the newest N spans. `--critical-path`
/// and `--stragglers` switch to the cross-process cluster analyses over a
/// merged `fit --cluster --trace` timeline.
fn cmd_trace(argv: Vec<String>) -> anyhow::Result<()> {
    let mut argv = argv;
    // Accept the file positionally (`repro trace trace.jsonl`).
    let positional = argv.first().map(|f| !f.starts_with("--")).unwrap_or(false);
    if positional {
        let file = argv.remove(0);
        argv.insert(0, format!("--file={file}"));
    }
    let spec = Spec::new("trace", "pretty-print / analyze a JSONL span trace")
        .req("file", "trace file written by --trace (positional also accepted)")
        .opt("last", "0", "show only the newest N spans (0 = all)")
        .opt("name", "", "keep spans whose name contains this substring (plus ancestors)")
        .switch(
            "critical-path",
            "per-pass wall-time attribution (compute/decode/io-prefetch/network/\
             straggler-wait per worker) + the longest dependency chain",
        )
        .switch(
            "stragglers",
            "rank workers by shard_task p50 latency and flag those above the fleet \
             median x --straggler-factor",
        )
        .opt("straggler-factor", "2.0", "straggler threshold multiplier over the fleet median");
    let args = parse(spec, &argv)?;
    let path = Path::new(args.str("file"));
    let trace = telemetry::trace::read_jsonl(path).map_err(|e| anyhow::anyhow!("{e}"))?;
    let critical = args.bool("critical-path")?;
    let straggle = args.bool("stragglers")?;
    if critical {
        print!("{}", telemetry::trace::critical_path_report(&trace));
    }
    if straggle {
        let factor = args.f64("straggler-factor")?;
        // The report's last line is the machine-scrapable verdict
        // ("stragglers: <addrs>" / "no stragglers") the CI smoke greps.
        let (report, _flagged) = telemetry::trace::stragglers_report(&trace, factor);
        print!("{report}");
    }
    if !critical && !straggle {
        let name = args.str("name");
        let filter = if name.is_empty() { None } else { Some(name) };
        print!("{}", telemetry::trace::render_tree(&trace, args.usize("last")?, filter));
    }
    println!("({} spans, {} dropped)", trace.spans.len(), trace.dropped);
    Ok(())
}

fn cmd_nu(argv: Vec<String>) -> anyhow::Result<()> {
    let spec = scale_flags(Spec::new("nu-sweep", "Figure 3: nu sensitivity"))
        .opt("workload", "generalization", "workload preset: generalization|standard")
        .opt("nus", "0.0005,0.002,0.01,0.05,0.2,1.0", "nu grid")
        .opt("q", "2", "rcca power iterations")
        .opt("p", "240", "rcca oversampling")
        .opt("horst-passes", "120", "Horst budget")
        .opt("report-dir", "reports", "where JSON twins are written");
    let args = parse(spec, &argv)?;
    let w = Workload::generate(scale_from(&args)?);
    let (q, p) = (args.usize("q")?, args.usize("p")?);
    let budget = args.usize("horst-passes")?;
    let pts = experiments::e4_nu::run(&w, &args.f64_list("nus")?, q, p, budget)?;
    if let Err(msg) = experiments::e4_nu::check_shape(&pts) {
        eprintln!("warning: figure-3 shape check: {msg}");
    }
    emit(
        &experiments::e4_nu::report(&pts, q, p, budget),
        args.str("report-dir"),
    )
}
