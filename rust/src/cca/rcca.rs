//! RandomizedCCA — the paper's Algorithm 1, verbatim over a [`PassEngine`].

use super::pass::PassEngine;
use super::CcaModel;
use crate::linalg::{
    cholesky, matmul, matmul_tn, orth, solve_lower, solve_lower_transpose, svd::svd_truncated, Mat,
};
use crate::linalg::solve::right_solve_lower_transpose;
use crate::util::rng::Rng;
use anyhow::{Context, Result};

/// Hyperparameters of Algorithm 1.
#[derive(Debug, Clone)]
pub struct RccaConfig {
    /// Target embedding dimension `k` (paper uses k = 60).
    pub k: usize,
    /// Oversampling `p` — the paper's central knob (effective rank k+p).
    pub p: usize,
    /// Power-iteration passes `q` (0 = pure sketch, 1–3 in the paper).
    pub q: usize,
    /// Ridge regularizers λa, λb. Use [`super::scale_free_lambda`] for the
    /// paper's ν-parameterization.
    pub lambda_a: f64,
    pub lambda_b: f64,
    pub seed: u64,
}

impl Default for RccaConfig {
    fn default() -> Self {
        RccaConfig {
            k: 60,
            p: 100,
            q: 1,
            lambda_a: 1e-3,
            lambda_b: 1e-3,
            seed: 0xcca,
        }
    }
}

/// The RandomizedCCA solver.
pub struct RandomizedCca {
    pub config: RccaConfig,
}

impl RandomizedCca {
    pub fn new(config: RccaConfig) -> RandomizedCca {
        RandomizedCca { config }
    }

    /// Run Algorithm 1. Pass count: `q` range-finder passes + 1 final pass.
    ///
    /// Returns the model and, for reuse by warm-started baselines
    /// (Horst+rcca), the orthonormal bases `(Qa, Qb)` of the final step.
    pub fn fit_with_bases<E: PassEngine + ?Sized>(
        &self,
        engine: &mut E,
    ) -> Result<(CcaModel, Mat, Mat)> {
        let cfg = &self.config;
        let (n, da, db) = engine.dims();
        let r = cfg.k + cfg.p;
        anyhow::ensure!(cfg.k > 0, "k must be positive");
        anyhow::ensure!(r <= da.min(db), "k+p={} exceeds min(da,db)={}", r, da.min(db));
        anyhow::ensure!(
            cfg.lambda_a > 0.0 && cfg.lambda_b > 0.0,
            "regularizers must be positive (paper §3: λ controls the relevant rank)"
        );
        let mut rng = Rng::new(cfg.seed);

        // Lines 2–4: Gaussian test matrices. (The paper's "structured
        // randomness suitable for dense A,B" alternative — an SRHT — applies
        // when the views are dense; hashed BoW is sparse, so Gaussian.)
        let mut qa = Mat::randn(da, r, &mut rng);
        let mut qb = Mat::randn(db, r, &mut rng);

        // Lines 5–12: randomized range finder with q power iterations.
        for _ in 0..cfg.q {
            let (ya, yb) = engine.power_pass(&qa, &qb);
            qa = orth(&ya);
            qb = orth(&yb);
        }

        // Lines 14–18: final pass for the small matrices.
        let (ca, cb, f) = engine.final_pass(&qa, &qb);

        // Lines 19–20: La = chol(Ca + λa QaᵀQa). For q ≥ 1, QaᵀQa = I, but
        // for q = 0 the Gaussian Qa is not orthonormal and the general form
        // is required.
        let mut ga = ca;
        let qa_gram = matmul_tn(&qa, &qa).scaled(cfg.lambda_a);
        ga.add_assign(&qa_gram);
        let la = cholesky(&ga).context("view A: Ca + λa·QaᵀQa not PD")?;

        let mut gb = cb;
        let qb_gram = matmul_tn(&qb, &qb).scaled(cfg.lambda_b);
        gb.add_assign(&qb_gram);
        let lb = cholesky(&gb).context("view B: Cb + λb·QbᵀQb not PD")?;

        // Line 21: F ← La⁻ᵀ F Lb⁻¹ (paper uses Matlab's upper-triangular
        // chol; with our lower-triangular La = chol(·) this is
        // F_w = La⁻¹ · F · Lb⁻ᵀ, so that (QaLa⁻ᵀ)ᵀ(AᵀA+λI)(QaLa⁻ᵀ) = I).
        let fw = right_solve_lower_transpose(&solve_lower(&la, &f), &lb);

        // Line 22: rank-k SVD.
        let (u, sigma, v) = svd_truncated(&fw, cfg.k);

        // Lines 23–24: map back, Xa = √n Qa La⁻¹ U (Matlab) = √n Qa La⁻ᵀ U.
        let sqrt_n = (n as f64).sqrt();
        let xa = matmul(&qa, &solve_lower_transpose(&la, &u)).scaled(sqrt_n);
        let xb = matmul(&qb, &solve_lower_transpose(&lb, &v)).scaled(sqrt_n);

        // σ returned by the algorithm is the singular values of the
        // whitened F; with the √n scaling these are the canonical
        // correlation estimates directly (unit-variance constraint holds).
        Ok((
            CcaModel {
                xa,
                xb,
                sigma,
                passes: engine.passes(),
            },
            qa,
            qb,
        ))
    }

    pub fn fit<E: PassEngine + ?Sized>(&self, engine: &mut E) -> Result<CcaModel> {
        Ok(self.fit_with_bases(engine)?.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cca::exact::exact_cca;
    use crate::cca::objective::{evaluate, feasibility};
    use crate::cca::pass::InMemoryPass;
    use crate::data::synthparl::{SynthParl, SynthParlConfig};
    use crate::data::TwoViewChunk;

    fn dataset(n: usize, dims: usize, seed: u64) -> TwoViewChunk {
        let d = SynthParl::generate(SynthParlConfig {
            n,
            dims,
            topics: 8,
            words_per_topic: 10,
            background_words: 30,
            mean_len: 8.0,
            seed,
            ..Default::default()
        });
        TwoViewChunk { a: d.a, b: d.b }
    }

    #[test]
    fn pass_count_is_q_plus_one() {
        let mut eng = InMemoryPass::new(dataset(300, 64, 1));
        for q in 0..4 {
            let mut eng2 = InMemoryPass::new(eng.chunk().clone());
            let model = RandomizedCca::new(RccaConfig {
                k: 4,
                p: 8,
                q,
                ..Default::default()
            })
            .fit(&mut eng2)
            .unwrap();
            assert_eq!(model.passes, q + 1, "q={q}");
        }
    }

    #[test]
    fn output_shapes_and_sigma_order() {
        let mut eng = InMemoryPass::new(dataset(300, 64, 2));
        let model = RandomizedCca::new(RccaConfig {
            k: 5,
            p: 10,
            q: 1,
            ..Default::default()
        })
        .fit(&mut eng)
        .unwrap();
        assert_eq!((model.xa.rows, model.xa.cols), (64, 5));
        assert_eq!((model.xb.rows, model.xb.cols), (64, 5));
        assert_eq!(model.sigma.len(), 5);
        for w in model.sigma.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        // Canonical correlations are in [0, 1] up to numerical slack.
        assert!(model.sigma[0] <= 1.0 + 1e-6);
        assert!(model.sigma.iter().all(|&s| s >= -1e-12));
    }

    #[test]
    fn solution_is_feasible_to_machine_precision() {
        // Paper §4: "in all cases the solutions found are feasible to
        // machine precision".
        let chunk = dataset(400, 64, 3);
        let mut eng = InMemoryPass::new(chunk);
        let cfg = RccaConfig {
            k: 4,
            p: 12,
            q: 2,
            lambda_a: 0.05,
            lambda_b: 0.05,
            seed: 7,
        };
        let model = RandomizedCca::new(cfg.clone()).fit(&mut eng).unwrap();
        let feas = feasibility(&model, &mut eng, cfg.lambda_a, cfg.lambda_b);
        assert!(feas.cov_a_err < 1e-8, "cov_a {}", feas.cov_a_err);
        assert!(feas.cov_b_err < 1e-8, "cov_b {}", feas.cov_b_err);
        assert!(feas.cross_offdiag < 1e-8, "offdiag {}", feas.cross_offdiag);
    }

    #[test]
    fn full_oversampling_matches_exact_cca() {
        // With k+p = d and q ≥ 2 the range finder spans everything, so
        // RandomizedCCA must agree with the exact (dense, whitened-SVD)
        // oracle on correlations.
        let chunk = dataset(500, 32, 4);
        let lambda = 0.1;
        let exact = exact_cca(
            &chunk.a.to_dense(),
            &chunk.b.to_dense(),
            4,
            lambda,
            lambda,
        );
        let mut eng = InMemoryPass::new(chunk);
        let model = RandomizedCca::new(RccaConfig {
            k: 4,
            p: 28, // k+p = 32 = d
            q: 2,
            lambda_a: lambda,
            lambda_b: lambda,
            seed: 11,
        })
        .fit(&mut eng)
        .unwrap();
        for i in 0..4 {
            assert!(
                (model.sigma[i] - exact.sigma[i]).abs() < 1e-6,
                "σ_{i}: rcca {} exact {}",
                model.sigma[i],
                exact.sigma[i]
            );
        }
    }

    #[test]
    fn more_oversampling_is_better() {
        // The paper's Figure 2a trend: objective increases with p (at fixed
        // q), approaching the exact optimum.
        let chunk = dataset(600, 96, 5);
        let mut sums = Vec::new();
        for p in [2usize, 16, 64] {
            let mut eng = InMemoryPass::new(chunk.clone());
            let model = RandomizedCca::new(RccaConfig {
                k: 6,
                p,
                q: 1,
                lambda_a: 0.05,
                lambda_b: 0.05,
                seed: 13,
            })
            .fit(&mut eng)
            .unwrap();
            let obj = evaluate(&model, &mut eng);
            sums.push(obj.sum_corr);
        }
        assert!(sums[0] <= sums[1] + 1e-3, "{sums:?}");
        assert!(sums[1] <= sums[2] + 1e-3, "{sums:?}");
    }

    #[test]
    fn power_iterations_help_at_fixed_p() {
        // Figure 2a's other axis: q=1 ≫ q=0.
        let chunk = dataset(600, 96, 6);
        let run = |q: usize| {
            let mut eng = InMemoryPass::new(chunk.clone());
            let model = RandomizedCca::new(RccaConfig {
                k: 6,
                p: 10,
                q,
                lambda_a: 0.05,
                lambda_b: 0.05,
                seed: 17,
            })
            .fit(&mut eng)
            .unwrap();
            evaluate(&model, &mut eng).sum_corr
        };
        let (s0, s1) = (run(0), run(1));
        assert!(s1 > s0, "q=1 ({s1}) should beat q=0 ({s0})");
    }

    #[test]
    fn rejects_bad_config() {
        let mut eng = InMemoryPass::new(dataset(100, 32, 7));
        assert!(RandomizedCca::new(RccaConfig {
            k: 0,
            ..Default::default()
        })
        .fit(&mut eng)
        .is_err());
        assert!(RandomizedCca::new(RccaConfig {
            k: 30,
            p: 10,
            ..Default::default()
        })
        .fit(&mut eng)
        .is_err());
        assert!(RandomizedCca::new(RccaConfig {
            k: 4,
            p: 4,
            lambda_a: 0.0,
            ..Default::default()
        })
        .fit(&mut eng)
        .is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let chunk = dataset(300, 48, 8);
        let cfg = RccaConfig {
            k: 3,
            p: 8,
            q: 1,
            seed: 99,
            ..Default::default()
        };
        let m1 = RandomizedCca::new(cfg.clone())
            .fit(&mut InMemoryPass::new(chunk.clone()))
            .unwrap();
        let m2 = RandomizedCca::new(cfg)
            .fit(&mut InMemoryPass::new(chunk))
            .unwrap();
        assert!(m1.xa.rel_diff(&m2.xa) < 1e-14);
        assert_eq!(m1.sigma, m2.sigma);
    }
}
