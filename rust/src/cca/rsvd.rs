//! Two-pass randomized SVD of the cross-covariance `(1/n)AᵀB`.
//!
//! The paper's Figure 1 ("Spectrum of (1/n)AᵀB … as estimated by two-pass
//! randomized SVD") uses exactly this: one pass to sketch the range, one
//! pass to project, then a small exact SVD (Halko–Martinsson–Tropp).

use super::pass::PassEngine;
use crate::linalg::{orth, svd::svd_thin, Mat};
use crate::util::rng::Rng;

/// Estimate the top-`s` singular values of `(1/n)AᵀB` with two data passes.
/// `oversample` extra sketch columns improve tail accuracy.
pub fn rsvd_spectrum<E: PassEngine + ?Sized>(
    engine: &mut E,
    s: usize,
    oversample: usize,
    seed: u64,
) -> Vec<f64> {
    let (n, da, db) = engine.dims();
    let r = (s + oversample).min(da.min(db));
    let mut rng = Rng::new(seed);

    // Pass 1: sketch both ranges. power_pass gives Ya = AᵀB·Ωb (range of
    // M = AᵀB) — Ωa's output is unused but comes for free in the same pass.
    let omega_a = Mat::randn(da, r, &mut rng);
    let omega_b = Mat::randn(db, r, &mut rng);
    let (ya, _yb) = engine.power_pass(&omega_a, &omega_b);
    let q = orth(&ya); // da × r basis for range(M)

    // Pass 2: Z = MᵀQ = BᵀA·Q (power_pass with qa = Q; Yb output).
    let zero = Mat::zeros(db, r);
    let (_ya2, z) = engine.power_pass(&q, &zero);

    // M ≈ Q·Zᵀ; singular values of M are those of Z (db × r, tall).
    let (_u, mut sigma, _v) = svd_thin(&z);
    for v in sigma.iter_mut() {
        *v /= n as f64;
    }
    sigma.truncate(s);
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cca::pass::InMemoryPass;
    use crate::data::synthparl::{SynthParl, SynthParlConfig};
    use crate::data::TwoViewChunk;
    use crate::linalg::gemm::matmul_tn as mm_tn;

    fn dataset(n: usize, dims: usize, seed: u64) -> TwoViewChunk {
        let d = SynthParl::generate(SynthParlConfig {
            n,
            dims,
            topics: 8,
            words_per_topic: 10,
            background_words: 20,
            mean_len: 8.0,
            seed,
            ..Default::default()
        });
        TwoViewChunk { a: d.a, b: d.b }
    }

    #[test]
    fn uses_exactly_two_passes() {
        let mut eng = InMemoryPass::new(dataset(200, 48, 1));
        let _ = rsvd_spectrum(&mut eng, 8, 4, 7);
        assert_eq!(eng.passes(), 2);
    }

    #[test]
    fn matches_dense_svd_head() {
        let chunk = dataset(400, 48, 2);
        let m = mm_tn(&chunk.a.to_dense(), &chunk.b.to_dense()).scaled(1.0 / 400.0);
        let (_, dense_sigma, _) = svd_thin(&m);
        let mut eng = InMemoryPass::new(chunk);
        // Full-width sketch → must match the dense spectrum closely.
        let est = rsvd_spectrum(&mut eng, 10, 38, 3);
        for i in 0..10 {
            let rel = (est[i] - dense_sigma[i]).abs() / dense_sigma[0];
            assert!(rel < 1e-8, "σ_{i}: est {} dense {}", est[i], dense_sigma[i]);
        }
    }

    #[test]
    fn modest_oversampling_captures_head() {
        let chunk = dataset(400, 96, 3);
        let m = mm_tn(&chunk.a.to_dense(), &chunk.b.to_dense()).scaled(1.0 / 400.0);
        let (_, dense_sigma, _) = svd_thin(&m);
        let mut eng = InMemoryPass::new(chunk);
        let est = rsvd_spectrum(&mut eng, 5, 20, 4);
        // Head estimates within 10% (random sketch, noisy tail is fine).
        for i in 0..3 {
            let rel = (est[i] - dense_sigma[i]).abs() / dense_sigma[i];
            assert!(rel < 0.1, "σ_{i} rel err {rel}");
        }
    }

    #[test]
    fn output_is_descending_nonnegative() {
        let mut eng = InMemoryPass::new(dataset(300, 64, 5));
        let est = rsvd_spectrum(&mut eng, 12, 8, 6);
        assert_eq!(est.len(), 12);
        for w in est.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(est.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn sketch_width_clamped_to_dims() {
        let mut eng = InMemoryPass::new(dataset(100, 32, 7));
        let est = rsvd_spectrum(&mut eng, 40, 50, 8); // would exceed d=32
        assert!(est.len() <= 32);
    }
}
