//! CCA core: RandomizedCCA (the paper's Algorithm 1), the Horst-iteration
//! baseline, the two-pass randomized SVD used for spectrum estimation
//! (Figure 1), objective/feasibility evaluation, and an exact small-scale
//! CCA oracle used as a correctness reference.
//!
//! All algorithms are written against the [`PassEngine`] trait, which
//! abstracts "one pass over the data computing batched products". Two
//! implementations exist:
//! * [`InMemoryPass`] — direct sparse products over an in-core dataset
//!   (single node, used by tests and small runs);
//! * `coordinator::ShardedPass` — the distributed leader/worker execution
//!   over on-disk shards, with chunk products computed by a
//!   [`crate::runtime::ChunkEngine`] (native Rust or AOT-compiled XLA).
//!
//! The trait's pass ledger is load-bearing: the paper's central claims are
//! about *data-pass counts*, so every implementation increments `passes()`
//! exactly once per sweep over the data, and the experiment harnesses
//! report it.

pub mod center;
pub mod exact;
pub mod horst;
pub mod objective;
pub mod pass;
pub mod rcca;
pub mod rsvd;

pub use center::{csr_column_means, CenteredPass, Means};
pub use exact::exact_cca;
pub use horst::{Horst, HorstConfig};
pub use objective::{evaluate, feasibility, Objective};
pub use pass::{InMemoryPass, PassEngine};
pub use rcca::{RandomizedCca, RccaConfig};
pub use rsvd::rsvd_spectrum;

use crate::linalg::Mat;

/// A fitted CCA model: per-view projections and the estimated canonical
/// correlations (Algorithm 1's return value `(Xa, Xb, Σ)`).
#[derive(Debug, Clone)]
pub struct CcaModel {
    /// da × k projection for view A.
    pub xa: Mat,
    /// db × k projection for view B.
    pub xb: Mat,
    /// Estimated canonical correlations (length k, descending).
    pub sigma: Vec<f64>,
    /// Data passes consumed to fit this model.
    pub passes: usize,
}

impl CcaModel {
    pub fn k(&self) -> usize {
        self.sigma.len()
    }

    /// Sum of the estimated canonical correlations (the paper's headline
    /// objective `(1/n)·Tr(XaᵀAᵀBXb)` equals this at the fitted point).
    pub fn sum_correlations(&self) -> f64 {
        self.sigma.iter().sum()
    }
}

/// Scale-free regularization from the paper's §4:
/// `λ = ν·tr(AᵀA)/d` (and analogously for B).
pub fn scale_free_lambda(nu: f64, gram_trace: f64, dims: usize) -> f64 {
    nu * gram_trace / dims as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_free_lambda_matches_formula() {
        let l = scale_free_lambda(0.01, 1000.0, 500);
        assert!((l - 0.02).abs() < 1e-15);
    }

    #[test]
    fn model_summaries() {
        let m = CcaModel {
            xa: Mat::zeros(4, 2),
            xb: Mat::zeros(4, 2),
            sigma: vec![0.9, 0.5],
            passes: 3,
        };
        assert_eq!(m.k(), 2);
        assert!((m.sum_correlations() - 1.4).abs() < 1e-15);
    }
}
