//! Objective and feasibility evaluation.
//!
//! The paper's reported metric is `(1/n)·Tr(XaᵀAᵀBXb)` — the sum of the
//! first k canonical correlations at the fitted point (Figure 2a's y-axis
//! and Table 2b's Train/Test columns). Feasibility (§4: "solutions found
//! are feasible to machine precision") means the regularized projection
//! covariances equal n·I and the cross covariance is diagonal.

use super::pass::PassEngine;
use super::CcaModel;
use crate::linalg::{matmul_tn, Mat};

/// Evaluation result on one dataset (train or test).
#[derive(Debug, Clone)]
pub struct Objective {
    /// `(1/n)·Tr(XaᵀAᵀBXb)` — sum of canonical correlations.
    pub sum_corr: f64,
    /// Per-direction correlations `diag(XaᵀAᵀBXb)/n`.
    pub corrs: Vec<f64>,
}

/// Evaluate the objective of a fitted model on the engine's dataset
/// (one data pass). Works for held-out data by constructing the engine
/// over the test split.
pub fn evaluate<E: PassEngine + ?Sized>(model: &CcaModel, engine: &mut E) -> Objective {
    let (n, _, _) = engine.dims();
    let (_ca, _cb, f) = engine.final_pass(&model.xa, &model.xb);
    let inv_n = 1.0 / n as f64;
    let corrs: Vec<f64> = (0..model.k()).map(|i| f[(i, i)] * inv_n).collect();
    Objective {
        sum_corr: corrs.iter().sum(),
        corrs,
    }
}

/// Feasibility diagnostics (one data pass).
#[derive(Debug, Clone)]
pub struct Feasibility {
    /// ‖Xaᵀ(AᵀA + λa·I)Xa/n − I‖_max
    pub cov_a_err: f64,
    /// ‖Xbᵀ(BᵀB + λb·I)Xb/n − I‖_max
    pub cov_b_err: f64,
    /// max off-diagonal |(XaᵀAᵀBXb)_ij| / n
    pub cross_offdiag: f64,
}

/// Check the KKT feasibility conditions of a fitted model.
pub fn feasibility<E: PassEngine + ?Sized>(
    model: &CcaModel,
    engine: &mut E,
    lambda_a: f64,
    lambda_b: f64,
) -> Feasibility {
    let (n, _, _) = engine.dims();
    let inv_n = 1.0 / n as f64;
    let (ca, cb, f) = engine.final_pass(&model.xa, &model.xb);

    let reg_cov = |c: &Mat, x: &Mat, lambda: f64| -> f64 {
        let mut g = c.clone();
        g.add_assign(&matmul_tn(x, x).scaled(lambda));
        g.scale(inv_n);
        let k = g.rows;
        g.sub(&Mat::eye(k)).max_abs()
    };

    let cov_a_err = reg_cov(&ca, &model.xa, lambda_a);
    let cov_b_err = reg_cov(&cb, &model.xb, lambda_b);

    let mut cross_offdiag = 0.0f64;
    for i in 0..f.rows {
        for j in 0..f.cols {
            if i != j {
                cross_offdiag = cross_offdiag.max((f[(i, j)] * inv_n).abs());
            }
        }
    }
    Feasibility {
        cov_a_err,
        cov_b_err,
        cross_offdiag,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cca::pass::InMemoryPass;
    use crate::cca::rcca::{RandomizedCca, RccaConfig};
    use crate::data::synthparl::{SynthParl, SynthParlConfig};
    use crate::data::TwoViewChunk;

    fn fit_small() -> (CcaModel, InMemoryPass, f64) {
        let d = SynthParl::generate(SynthParlConfig {
            n: 400,
            dims: 64,
            topics: 6,
            words_per_topic: 10,
            background_words: 20,
            mean_len: 8.0,
            seed: 123,
            ..Default::default()
        });
        let chunk = TwoViewChunk { a: d.a, b: d.b };
        let mut eng = InMemoryPass::new(chunk);
        let lambda = 0.05;
        let model = RandomizedCca::new(RccaConfig {
            k: 4,
            p: 16,
            q: 2,
            lambda_a: lambda,
            lambda_b: lambda,
            seed: 5,
        })
        .fit(&mut eng)
        .unwrap();
        (model, eng, lambda)
    }

    #[test]
    fn objective_matches_model_sigma() {
        // At the fitted point on the training data, evaluate() must agree
        // with the σ the algorithm returned.
        let (model, mut eng, _) = fit_small();
        let obj = evaluate(&model, &mut eng);
        assert!((obj.sum_corr - model.sum_correlations()).abs() < 1e-8);
        for (a, b) in obj.corrs.iter().zip(&model.sigma) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn corrs_are_descending_at_fit() {
        let (model, mut eng, _) = fit_small();
        let obj = evaluate(&model, &mut eng);
        for w in obj.corrs.windows(2) {
            assert!(w[0] >= w[1] - 1e-9);
        }
    }

    #[test]
    fn feasibility_near_zero_at_fit() {
        let (model, mut eng, lambda) = fit_small();
        let f = feasibility(&model, &mut eng, lambda, lambda);
        assert!(f.cov_a_err < 1e-8);
        assert!(f.cov_b_err < 1e-8);
        assert!(f.cross_offdiag < 1e-8);
    }

    #[test]
    fn feasibility_detects_violations() {
        // Scale one projection — covariance constraint must fire.
        let (mut model, mut eng, lambda) = fit_small();
        model.xa.scale(2.0);
        let f = feasibility(&model, &mut eng, lambda, lambda);
        assert!(f.cov_a_err > 1.0, "{}", f.cov_a_err);
    }

    #[test]
    fn held_out_objective_lower_than_train() {
        // Generic learning sanity on a split: test ≤ train (+slack).
        use crate::data::split::{gather_rows, split_indices};
        let d = SynthParl::generate(SynthParlConfig {
            n: 1200,
            dims: 64,
            topics: 6,
            words_per_topic: 10,
            background_words: 20,
            mean_len: 8.0,
            seed: 321,
            ..Default::default()
        });
        let (tr, te) = split_indices(1200, 0.25, 9);
        let train = TwoViewChunk {
            a: gather_rows(&d.a, &tr),
            b: gather_rows(&d.b, &tr),
        };
        let test = TwoViewChunk {
            a: gather_rows(&d.a, &te),
            b: gather_rows(&d.b, &te),
        };
        let mut eng_tr = InMemoryPass::new(train);
        let model = RandomizedCca::new(RccaConfig {
            k: 4,
            p: 20,
            q: 2,
            lambda_a: 0.05,
            lambda_b: 0.05,
            seed: 31,
        })
        .fit(&mut eng_tr)
        .unwrap();
        let train_obj = evaluate(&model, &mut eng_tr).sum_corr;
        let mut eng_te = InMemoryPass::new(test);
        let test_obj = evaluate(&model, &mut eng_te).sum_corr;
        assert!(
            test_obj <= train_obj + 0.1,
            "test {test_obj} train {train_obj}"
        );
        // And the learned structure must transfer at all.
        assert!(test_obj > 0.0);
    }
}
