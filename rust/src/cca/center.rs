//! Mean-centering as a pass-engine wrapper.
//!
//! The paper (§3) elides mean shifting "which is a rank one update, and can
//! be done in O(da+db) extra space without introducing additional data
//! passes and preserving sparsity". This module implements exactly that:
//! [`CenteredPass`] wraps any [`PassEngine`] and corrects every pass output
//! with the rank-one terms, using only the cached column means:
//!
//! ```text
//! (A−1μaᵀ)ᵀ(B−1μbᵀ) = AᵀB − n·μa·μbᵀ          (since Aᵀ1 = n·μa)
//! ⇒ Ya_c = Ya − n·μa·(μbᵀQb),   Ca_c = Ca − n·(Qaᵀμa)(Qaᵀμa)ᵀ, …
//! ```
//!
//! The means themselves are one extra pass at construction (in a real
//! deployment they are folded into shard-writing statistics, as the paper
//! notes); thereafter every pass has zero extra data cost and sparsity is
//! never broken.

use super::pass::PassEngine;
use crate::linalg::Mat;

/// Column means of both views (the rank-one state).
#[derive(Debug, Clone)]
pub struct Means {
    pub mu_a: Vec<f64>,
    pub mu_b: Vec<f64>,
}

/// A pass engine computing over implicitly mean-centered views.
pub struct CenteredPass<E: PassEngine> {
    inner: E,
    means: Means,
}

impl<E: PassEngine> CenteredPass<E> {
    /// Wrap `inner`, computing the column means with one dedicated pass.
    ///
    /// The mean of view A is `Aᵀ1/n`, obtainable from a power-type pass
    /// against a fixed all-ones single-column Q: `power_pass(1ₐ, 1_b)`
    /// yields `Aᵀ(B·1)` — not the mean. Instead we use the final-pass
    /// trick: with Qa = Qb = [e] where e is all-ones scaled by 1/n … no
    /// single existing product yields Aᵀ1 directly, so implementations
    /// that own the data (InMemoryPass / ShardedPass) expose it cheaply;
    /// here we compute means from a caller-provided closure over the data
    /// or via [`CenteredPass::with_means`].
    pub fn with_means(inner: E, means: Means) -> CenteredPass<E> {
        let (_, da, db) = inner.dims();
        assert_eq!(means.mu_a.len(), da);
        assert_eq!(means.mu_b.len(), db);
        CenteredPass { inner, means }
    }

    pub fn means(&self) -> &Means {
        &self.means
    }

    pub fn into_inner(self) -> E {
        self.inner
    }
}

/// Column means of a CSR matrix (used to build [`Means`] for in-core data;
/// O(nnz), one sweep — shard writers record this at ingest in deployment).
pub fn csr_column_means(c: &crate::sparse::Csr) -> Vec<f64> {
    let mut mu = vec![0.0f64; c.cols];
    for i in 0..c.rows {
        let (idx, vals) = c.row(i);
        for (&j, &v) in idx.iter().zip(vals) {
            mu[j as usize] += v as f64;
        }
    }
    let n = c.rows.max(1) as f64;
    for m in mu.iter_mut() {
        *m /= n;
    }
    mu
}

/// μᵀ·Q for a d-vector μ and d×r matrix Q → 1×r row.
fn mu_t_q(mu: &[f64], q: &Mat) -> Vec<f64> {
    assert_eq!(mu.len(), q.rows);
    let mut out = vec![0.0f64; q.cols];
    for (i, &m) in mu.iter().enumerate() {
        if m == 0.0 {
            continue;
        }
        for (o, qv) in out.iter_mut().zip(q.row(i)) {
            *o += m * qv;
        }
    }
    out
}

impl<E: PassEngine> PassEngine for CenteredPass<E> {
    fn dims(&self) -> (usize, usize, usize) {
        self.inner.dims()
    }

    fn power_pass(&mut self, qa: &Mat, qb: &Mat) -> (Mat, Mat) {
        let (n, _, _) = self.inner.dims();
        let nf = n as f64;
        let (mut ya, mut yb) = self.inner.power_pass(qa, qb);
        // Ya_c = Ya − n·μa·(μbᵀQb);  Yb_c = Yb − n·μb·(μaᵀQa).
        let mbq = mu_t_q(&self.means.mu_b, qb);
        for i in 0..ya.rows {
            let mu = self.means.mu_a[i];
            if mu == 0.0 {
                continue;
            }
            for (v, s) in ya.row_mut(i).iter_mut().zip(&mbq) {
                *v -= nf * mu * s;
            }
        }
        let maq = mu_t_q(&self.means.mu_a, qa);
        for i in 0..yb.rows {
            let mu = self.means.mu_b[i];
            if mu == 0.0 {
                continue;
            }
            for (v, s) in yb.row_mut(i).iter_mut().zip(&maq) {
                *v -= nf * mu * s;
            }
        }
        (ya, yb)
    }

    fn final_pass(&mut self, qa: &Mat, qb: &Mat) -> (Mat, Mat, Mat) {
        let (n, _, _) = self.inner.dims();
        let nf = n as f64;
        let (mut ca, mut cb, mut f) = self.inner.final_pass(qa, qb);
        // Pa = AQa: centered Gram = Ca − n·(Qaᵀμa)(Qaᵀμa)ᵀ, etc.
        let sa = mu_t_q(&self.means.mu_a, qa);
        let sb = mu_t_q(&self.means.mu_b, qb);
        for i in 0..ca.rows {
            for j in 0..ca.cols {
                ca[(i, j)] -= nf * sa[i] * sa[j];
            }
        }
        for i in 0..cb.rows {
            for j in 0..cb.cols {
                cb[(i, j)] -= nf * sb[i] * sb[j];
            }
        }
        for i in 0..f.rows {
            for j in 0..f.cols {
                f[(i, j)] -= nf * sa[i] * sb[j];
            }
        }
        (ca, cb, f)
    }

    fn gram_traces(&mut self) -> (f64, f64) {
        let (n, _, _) = self.inner.dims();
        let nf = n as f64;
        let (ta, tb) = self.inner.gram_traces();
        // tr((A−1μᵀ)ᵀ(A−1μᵀ)) = tr(AᵀA) − n·‖μ‖².
        let norm2 = |mu: &[f64]| mu.iter().map(|m| m * m).sum::<f64>();
        (
            ta - nf * norm2(&self.means.mu_a),
            tb - nf * norm2(&self.means.mu_b),
        )
    }

    fn passes(&self) -> usize {
        self.inner.passes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cca::pass::InMemoryPass;
    use crate::cca::rcca::{RandomizedCca, RccaConfig};
    use crate::data::synthparl::{SynthParl, SynthParlConfig};
    use crate::data::TwoViewChunk;
    use crate::linalg::gemm::{matmul, matmul_tn};
    use crate::util::rng::Rng;

    fn dataset(n: usize, dims: usize, seed: u64) -> TwoViewChunk {
        let d = SynthParl::generate(SynthParlConfig {
            n,
            dims,
            topics: 6,
            words_per_topic: 10,
            background_words: 20,
            mean_len: 8.0,
            normalize: false, // raw counts → non-trivial means
            seed,
            ..Default::default()
        });
        TwoViewChunk { a: d.a, b: d.b }
    }

    /// Densely center a matrix (test oracle).
    fn center_dense(m: &Mat) -> Mat {
        let n = m.rows as f64;
        let mut out = m.clone();
        for j in 0..m.cols {
            let mu: f64 = (0..m.rows).map(|i| m[(i, j)]).sum::<f64>() / n;
            for i in 0..m.rows {
                out[(i, j)] -= mu;
            }
        }
        out
    }

    fn centered_engine(chunk: &TwoViewChunk) -> CenteredPass<InMemoryPass> {
        let means = Means {
            mu_a: csr_column_means(&chunk.a),
            mu_b: csr_column_means(&chunk.b),
        };
        CenteredPass::with_means(InMemoryPass::new(chunk.clone()), means)
    }

    #[test]
    fn column_means_match_dense() {
        let chunk = dataset(200, 32, 1);
        let mu = csr_column_means(&chunk.a);
        let dense = chunk.a.to_dense();
        for j in 0..32 {
            let want: f64 = (0..200).map(|i| dense[(i, j)]).sum::<f64>() / 200.0;
            assert!((mu[j] - want).abs() < 1e-9);
        }
    }

    #[test]
    fn power_pass_matches_explicit_centering() {
        let chunk = dataset(300, 48, 2);
        let ac = center_dense(&chunk.a.to_dense());
        let bc = center_dense(&chunk.b.to_dense());
        let mut eng = centered_engine(&chunk);
        let mut rng = Rng::new(3);
        let qa = Mat::randn(48, 5, &mut rng);
        let qb = Mat::randn(48, 5, &mut rng);
        let (ya, yb) = eng.power_pass(&qa, &qb);
        let want_ya = matmul_tn(&ac, &matmul(&bc, &qb));
        let want_yb = matmul_tn(&bc, &matmul(&ac, &qa));
        assert!(ya.rel_diff(&want_ya) < 1e-4, "{}", ya.rel_diff(&want_ya));
        assert!(yb.rel_diff(&want_yb) < 1e-4);
    }

    #[test]
    fn final_pass_matches_explicit_centering() {
        let chunk = dataset(300, 48, 4);
        let ac = center_dense(&chunk.a.to_dense());
        let bc = center_dense(&chunk.b.to_dense());
        let mut eng = centered_engine(&chunk);
        let mut rng = Rng::new(5);
        let qa = Mat::randn(48, 4, &mut rng);
        let qb = Mat::randn(48, 4, &mut rng);
        let (ca, cb, f) = eng.final_pass(&qa, &qb);
        let pa = matmul(&ac, &qa);
        let pb = matmul(&bc, &qb);
        assert!(ca.rel_diff(&matmul_tn(&pa, &pa)) < 1e-4);
        assert!(cb.rel_diff(&matmul_tn(&pb, &pb)) < 1e-4);
        assert!(f.rel_diff(&matmul_tn(&pa, &pb)) < 1e-4);
    }

    #[test]
    fn gram_traces_match_centered_dense() {
        let chunk = dataset(250, 32, 6);
        let ac = center_dense(&chunk.a.to_dense());
        let mut eng = centered_engine(&chunk);
        let (ta, _) = eng.gram_traces();
        let want = matmul_tn(&ac, &ac).trace();
        assert!((ta - want).abs() / want.abs().max(1.0) < 1e-6);
    }

    #[test]
    fn no_extra_passes_after_construction() {
        let chunk = dataset(200, 32, 7);
        let mut eng = centered_engine(&chunk);
        let mut rng = Rng::new(8);
        let q = Mat::randn(32, 3, &mut rng);
        assert_eq!(eng.passes(), 0);
        eng.power_pass(&q, &q);
        eng.final_pass(&q, &q);
        // Each pass costs exactly one inner pass — the rank-one corrections
        // are free (the paper's claim).
        assert_eq!(eng.passes(), 2);
    }

    #[test]
    fn rcca_on_centered_engine_matches_exact_on_centered_data() {
        let chunk = dataset(500, 32, 9);
        let ac = center_dense(&chunk.a.to_dense());
        let bc = center_dense(&chunk.b.to_dense());
        let lambda = 0.1;
        let exact = crate::cca::exact::exact_cca(&ac, &bc, 3, lambda, lambda);
        let mut eng = centered_engine(&chunk);
        let model = RandomizedCca::new(RccaConfig {
            k: 3,
            p: 29, // full rank
            q: 2,
            lambda_a: lambda,
            lambda_b: lambda,
            seed: 10,
        })
        .fit(&mut eng)
        .unwrap();
        for i in 0..3 {
            assert!(
                (model.sigma[i] - exact.sigma[i]).abs() < 1e-6,
                "σ_{i}: centered rcca {} exact {}",
                model.sigma[i],
                exact.sigma[i]
            );
        }
    }

    #[test]
    fn centering_changes_the_solution_when_means_are_large() {
        // Sanity that the wrapper is not a no-op: uncentered vs centered
        // correlations differ on raw-count data.
        let chunk = dataset(400, 32, 11);
        let lambda = 0.1;
        let mut plain = InMemoryPass::new(chunk.clone());
        let m1 = RandomizedCca::new(RccaConfig {
            k: 3,
            p: 20,
            q: 2,
            lambda_a: lambda,
            lambda_b: lambda,
            seed: 12,
        })
        .fit(&mut plain)
        .unwrap();
        let mut centered = centered_engine(&chunk);
        let m2 = RandomizedCca::new(RccaConfig {
            k: 3,
            p: 20,
            q: 2,
            lambda_a: lambda,
            lambda_b: lambda,
            seed: 12,
        })
        .fit(&mut centered)
        .unwrap();
        let d: f64 = (0..3)
            .map(|i| (m1.sigma[i] - m2.sigma[i]).abs())
            .sum();
        assert!(d > 1e-4, "centering had no effect: {d}");
    }
}
