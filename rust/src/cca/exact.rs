//! Exact CCA oracle for small dense problems (test reference).
//!
//! Solves the regularized CCA problem by explicit whitening:
//! `T = (AᵀA + λaI)^{-1/2} · AᵀB · (BᵀB + λbI)^{-1/2}`, SVD of T, and
//! mapping back. O(d³) — only for test-scale d, as the paper notes ("for
//! moderate sized design matrices an SVD directly reveals the solution").

use super::CcaModel;
use crate::linalg::eig::inv_sqrt_spd;
use crate::linalg::svd::svd_truncated;
use crate::linalg::{matmul, matmul_tn, Mat};

/// Exact regularized CCA via whitened SVD on dense views.
pub fn exact_cca(a: &Mat, b: &Mat, k: usize, lambda_a: f64, lambda_b: f64) -> CcaModel {
    assert_eq!(a.rows, b.rows, "views must be row-aligned");
    let n = a.rows;
    let mut ca = matmul_tn(a, a);
    ca.add_diag(lambda_a);
    let mut cb = matmul_tn(b, b);
    cb.add_diag(lambda_b);
    let cab = matmul_tn(a, b);

    let wa = inv_sqrt_spd(&ca, 1e-12);
    let wb = inv_sqrt_spd(&cb, 1e-12);
    let t = matmul(&matmul(&wa, &cab), &wb);
    let (u, sigma, v) = svd_truncated(&t, k);

    let sqrt_n = (n as f64).sqrt();
    let xa = matmul(&wa, &u).scaled(sqrt_n);
    let xb = matmul(&wb, &v).scaled(sqrt_n);
    CcaModel {
        xa,
        xb,
        sigma,
        passes: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Construct two views with a known shared latent signal:
    /// A = Z·Wa + noise, B = Z·Wb + noise.
    fn correlated_views(
        n: usize,
        d: usize,
        latent: usize,
        noise: f64,
        rng: &mut Rng,
    ) -> (Mat, Mat) {
        let z = Mat::randn(n, latent, rng);
        let wa = Mat::randn(latent, d, rng);
        let wb = Mat::randn(latent, d, rng);
        let mut a = matmul(&z, &wa);
        let mut b = matmul(&z, &wb);
        for v in a.data.iter_mut() {
            *v += noise * rng.normal();
        }
        for v in b.data.iter_mut() {
            *v += noise * rng.normal();
        }
        (a, b)
    }

    #[test]
    fn identical_views_have_unit_correlations() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(200, 8, &mut rng);
        let m = exact_cca(&a, &a, 3, 1e-9, 1e-9);
        for s in &m.sigma {
            assert!((s - 1.0).abs() < 1e-6, "σ {s}");
        }
    }

    #[test]
    fn independent_views_have_near_zero_correlations() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(4000, 4, &mut rng);
        let b = Mat::randn(4000, 4, &mut rng);
        let m = exact_cca(&a, &b, 2, 1e-6, 1e-6);
        // Sample correlations of independent data scale as ~sqrt(d/n).
        assert!(m.sigma[0] < 0.15, "σ0 {}", m.sigma[0]);
    }

    #[test]
    fn shared_latent_signal_detected() {
        let mut rng = Rng::new(3);
        let (a, b) = correlated_views(500, 10, 3, 0.1, &mut rng);
        let m = exact_cca(&a, &b, 5, 1e-3, 1e-3);
        // Three strong canonical directions, then a gap.
        assert!(m.sigma[2] > 0.9, "{:?}", m.sigma);
        assert!(m.sigma[3] < 0.5, "{:?}", m.sigma);
    }

    #[test]
    fn feasibility_of_exact_solution() {
        prop::check("exact-cca-feasible", 10, |g| {
            let n = 100 + g.size(0, 100);
            let d = 4 + g.size(0, 8);
            let mut rng = Rng::new(g.seed);
            let (a, b) = correlated_views(n, d, 2, 0.5, &mut rng);
            let la = 0.1;
            let m = exact_cca(&a, &b, 2, la, la);
            // Xaᵀ(AᵀA+λI)Xa = n·I
            let mut ca = matmul_tn(&a, &a);
            ca.add_diag(la);
            let cov = matmul(&matmul_tn(&m.xa, &ca), &m.xa).scaled(1.0 / n as f64);
            assert!(
                cov.rel_diff(&Mat::eye(2)) < 1e-6,
                "cov err {}",
                cov.rel_diff(&Mat::eye(2))
            );
        });
    }

    #[test]
    fn invariant_to_joint_row_permutation() {
        let mut rng = Rng::new(4);
        let (a, b) = correlated_views(80, 6, 2, 0.3, &mut rng);
        let m1 = exact_cca(&a, &b, 2, 0.05, 0.05);
        // Permute rows of both views identically.
        let mut perm: Vec<usize> = (0..80).collect();
        rng.shuffle(&mut perm);
        let pa = Mat::from_rows(&perm.iter().map(|&i| a.row(i)).collect::<Vec<_>>());
        let pb = Mat::from_rows(&perm.iter().map(|&i| b.row(i)).collect::<Vec<_>>());
        let m2 = exact_cca(&pa, &pb, 2, 0.05, 0.05);
        for i in 0..2 {
            assert!((m1.sigma[i] - m2.sigma[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn regularization_shrinks_correlations() {
        let mut rng = Rng::new(5);
        let (a, b) = correlated_views(120, 8, 2, 0.4, &mut rng);
        let weak = exact_cca(&a, &b, 2, 1e-6, 1e-6);
        let strong = exact_cca(&a, &b, 2, 100.0, 100.0);
        assert!(strong.sigma[0] < weak.sigma[0]);
    }

    #[test]
    fn correlations_bounded_by_one() {
        prop::check("exact-cca-bounded", 10, |g| {
            let n = 50 + g.size(0, 50);
            let d = 3 + g.size(0, 5);
            let mut rng = Rng::new(g.seed);
            let (a, b) = correlated_views(n, d, 2, 0.2, &mut rng);
            let m = exact_cca(&a, &b, d.min(3), 1e-4, 1e-4);
            for s in &m.sigma {
                assert!(*s <= 1.0 + 1e-9 && *s >= -1e-12, "σ {s}");
            }
        });
    }
}
