//! Horst iteration — the paper's baseline (its footnote 5: "Gauss-Seidel
//! variant with approximate least squares solves and Gaussian random
//! initializer").
//!
//! Horst iteration is orthogonal power iteration for the multivariate
//! eigenvalue problem (3): each iteration multiplies the current block
//! iterates by the cross operator and re-normalizes each block in its
//! (regularized) covariance metric. We implement the subspace form with
//! *approximate least-squares solves realized as basis-restricted
//! whitening* (the solve `(AᵀA+λI)^{-1}·v` is applied exactly within the
//! span of the current basis — the inexactness the paper's reference [13]
//! shows is sufficient for convergence), optionally with the previous
//! iterate appended to the basis (a LOBPCG-style acceleration that makes
//! the objective monotone within the expanding subspace).
//!
//! Pass accounting: each iteration costs exactly **2 data passes** (one
//! multiplication pass, one normalization pass), so the paper's "budget of
//! 120 data passes" is 60 iterations here; the harness reports passes, not
//! iterations, to keep the comparison honest.

use super::pass::PassEngine;
use super::CcaModel;
use crate::linalg::solve::right_solve_lower_transpose;
use crate::linalg::{
    cholesky, matmul, matmul_tn, orth, solve_lower, solve_lower_transpose,
    svd::svd_truncated, Mat,
};
use crate::util::rng::Rng;
use anyhow::{Context, Result};

#[derive(Debug, Clone)]
pub struct HorstConfig {
    pub k: usize,
    pub lambda_a: f64,
    pub lambda_b: f64,
    /// Total data-pass budget (the paper reports 120).
    pub pass_budget: usize,
    /// Append the previous iterate to the basis (momentum/LOBPCG flavour).
    pub augment: bool,
    pub seed: u64,
    /// Stop early when the objective improves by less than `tol` for two
    /// consecutive iterations (0.0 disables early stopping — the paper runs
    /// a fixed budget).
    pub tol: f64,
}

impl Default for HorstConfig {
    fn default() -> Self {
        HorstConfig {
            k: 60,
            lambda_a: 1e-3,
            lambda_b: 1e-3,
            pass_budget: 120,
            augment: true,
            seed: 0x4057,
            tol: 0.0,
        }
    }
}

/// Per-iteration trace entry (passes so far, objective) — Figure 2a's
/// dashed line and the Horst+rcca pass-count comparison use this.
#[derive(Debug, Clone)]
pub struct HorstTrace {
    pub passes: usize,
    pub objective: f64,
}

pub struct Horst {
    pub config: HorstConfig,
}

impl Horst {
    pub fn new(config: HorstConfig) -> Horst {
        Horst { config }
    }

    /// Fit with a Gaussian random initializer (the paper's default).
    pub fn fit<E: PassEngine + ?Sized>(
        &self,
        engine: &mut E,
    ) -> Result<(CcaModel, Vec<HorstTrace>)> {
        let (_, da, db) = engine.dims();
        let mut rng = Rng::new(self.config.seed);
        let xa0 = Mat::randn(da, self.config.k, &mut rng);
        let xb0 = Mat::randn(db, self.config.k, &mut rng);
        self.fit_from(engine, xa0, xb0)
    }

    /// Fit from a warm start (Horst+rcca initializes from RandomizedCCA's
    /// solution; Table 2b's last row).
    pub fn fit_from<E: PassEngine + ?Sized>(
        &self,
        engine: &mut E,
        xa0: Mat,
        xb0: Mat,
    ) -> Result<(CcaModel, Vec<HorstTrace>)> {
        let cfg = &self.config;
        let (n, da, db) = engine.dims();
        anyhow::ensure!(cfg.k > 0 && cfg.k <= da.min(db), "bad k");
        anyhow::ensure!(cfg.lambda_a > 0.0 && cfg.lambda_b > 0.0, "λ must be > 0");
        anyhow::ensure!(xa0.cols == cfg.k && xb0.cols == cfg.k, "init shape mismatch");

        let start_passes = engine.passes();
        let mut xa = xa0;
        let mut xb = xb0;
        let mut best: Option<CcaModel> = None;
        let mut trace = Vec::new();
        let mut last_obj = f64::NEG_INFINITY;
        let mut stall = 0usize;
        // Previous iteration's basis + metric factor, used to apply the
        // *approximate least-squares solve*: (AᵀA+λI)⁻¹·y restricted to the
        // previous basis is Pa·(PaᵀMPa)⁻¹·Paᵀ·y = Pa·solve(La·Laᵀ, Paᵀy).
        let mut prev_a: Option<(Mat, Mat)> = None; // (basis, L)
        let mut prev_b: Option<(Mat, Mat)> = None;

        loop {
            let used = engine.passes() - start_passes;
            if used + 2 > cfg.pass_budget {
                break;
            }
            // Multiplication pass: Ya = AᵀB·Xb, Yb = BᵀA·Xa (Horst's block
            // matrix-multiply step).
            let (ya, yb) = engine.power_pass(&xa, &xb);

            // Approximate LS solve directions (preconditioned residual):
            // without them plain cross-power iteration stalls away from the
            // CCA optimum whenever AᵀA is far from identity.
            let precond = |y: &Mat, prev: &Option<(Mat, Mat)>| -> Option<Mat> {
                prev.as_ref().map(|(basis, l)| {
                    let w = matmul_tn(basis, y);
                    let z = crate::linalg::solve::solve_chol(l, &w);
                    matmul(basis, &z)
                })
            };
            let pa_dir = precond(&ya, &prev_a);
            let pb_dir = precond(&yb, &prev_b);

            // Basis for the solve + normalization: span{precond·Y, Y, X}.
            // Rayleigh–Ritz over this subspace makes the objective monotone
            // (with `augment`) and the preconditioned direction restores the
            // inverse-covariance geometry of the exact Horst update.
            // The augmented block can reach 3k columns; when that exceeds
            // the view dimension the span is the whole space anyway, so cap
            // at d columns (Y first — it carries the new directions) instead
            // of letting the thin-QR kernel panic on a wide input.
            let build_basis = |y: &Mat, x: &Mat, dir: Option<Mat>| -> Mat {
                let mut m = y.clone();
                if cfg.augment {
                    m = m.hcat(x);
                }
                if let Some(d) = dir {
                    m = m.hcat(&d);
                }
                if m.cols > m.rows {
                    m = m.cols_range(0, m.rows);
                }
                orth(&m)
            };
            let basis_a = build_basis(&ya, &xa, pa_dir);
            let basis_b = build_basis(&yb, &xb, pb_dir);

            // Normalization pass (block normalization in the covariance
            // metric, done exactly in the small basis).
            let (ca, cb, f) = engine.final_pass(&basis_a, &basis_b);
            let mut ga = ca;
            ga.add_assign(&matmul_tn(&basis_a, &basis_a).scaled(cfg.lambda_a));
            let la = cholesky(&ga).context("horst: view A metric not PD")?;
            let mut gb = cb;
            gb.add_assign(&matmul_tn(&basis_b, &basis_b).scaled(cfg.lambda_b));
            let lb = cholesky(&gb).context("horst: view B metric not PD")?;

            let fw = right_solve_lower_transpose(&solve_lower(&la, &f), &lb);
            let (u, sigma, v) = svd_truncated(&fw, cfg.k);
            let sqrt_n = (n as f64).sqrt();
            xa = matmul(&basis_a, &solve_lower_transpose(&la, &u)).scaled(sqrt_n);
            xb = matmul(&basis_b, &solve_lower_transpose(&lb, &v)).scaled(sqrt_n);
            prev_a = Some((basis_a, la));
            prev_b = Some((basis_b, lb));

            let obj: f64 = sigma.iter().sum();
            trace.push(HorstTrace {
                passes: engine.passes() - start_passes,
                objective: obj,
            });
            let model = CcaModel {
                xa: xa.clone(),
                xb: xb.clone(),
                sigma,
                passes: engine.passes() - start_passes,
            };
            let improved = obj
                > best
                    .as_ref()
                    .map(|m| m.sum_correlations())
                    .unwrap_or(f64::NEG_INFINITY);
            if improved {
                best = Some(model);
            }
            if cfg.tol > 0.0 {
                if obj - last_obj.max(0.0) < cfg.tol {
                    stall += 1;
                    if stall >= 2 {
                        break;
                    }
                } else {
                    stall = 0;
                }
            }
            last_obj = last_obj.max(obj);
        }
        let model = best.context("horst: pass budget too small for a single iteration")?;
        Ok((model, trace))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cca::exact::exact_cca;
    use crate::cca::objective::{evaluate, feasibility};
    use crate::cca::pass::InMemoryPass;
    use crate::cca::rcca::{RandomizedCca, RccaConfig};
    use crate::data::synthparl::{SynthParl, SynthParlConfig};
    use crate::data::TwoViewChunk;

    fn dataset(n: usize, dims: usize, seed: u64) -> TwoViewChunk {
        let d = SynthParl::generate(SynthParlConfig {
            n,
            dims,
            topics: 8,
            words_per_topic: 10,
            background_words: 30,
            mean_len: 8.0,
            seed,
            ..Default::default()
        });
        TwoViewChunk { a: d.a, b: d.b }
    }

    #[test]
    fn respects_pass_budget() {
        let mut eng = InMemoryPass::new(dataset(300, 48, 1));
        let (model, trace) = Horst::new(HorstConfig {
            k: 3,
            pass_budget: 10,
            lambda_a: 0.05,
            lambda_b: 0.05,
            ..Default::default()
        })
        .fit(&mut eng)
        .unwrap();
        assert!(model.passes <= 10);
        assert_eq!(trace.len(), 5); // 2 passes per iteration
        assert_eq!(trace.last().unwrap().passes, 10);
    }

    #[test]
    fn converges_to_exact_solution() {
        let chunk = dataset(500, 32, 2);
        let lambda = 0.1;
        let exact = exact_cca(&chunk.a.to_dense(), &chunk.b.to_dense(), 4, lambda, lambda);
        let mut eng = InMemoryPass::new(chunk);
        let (model, _) = Horst::new(HorstConfig {
            k: 4,
            lambda_a: lambda,
            lambda_b: lambda,
            pass_budget: 120,
            augment: true,
            seed: 3,
            tol: 0.0,
        })
        .fit(&mut eng)
        .unwrap();
        let sum_exact: f64 = exact.sigma.iter().sum();
        let sum_horst = model.sum_correlations();
        // The paper's Horst at a fixed budget is also not the exact optimum
        // (its Table 2b "Horst" rows differ from convergence); 1% is the
        // convergence criterion we hold the baseline to at this budget.
        assert!(
            (sum_exact - sum_horst).abs() < 1e-2 * sum_exact.abs().max(1.0),
            "horst {sum_horst} exact {sum_exact}"
        );
    }

    #[test]
    fn objective_is_monotone_with_augmentation() {
        let mut eng = InMemoryPass::new(dataset(400, 48, 4));
        let (_, trace) = Horst::new(HorstConfig {
            k: 4,
            pass_budget: 40,
            lambda_a: 0.05,
            lambda_b: 0.05,
            augment: true,
            ..Default::default()
        })
        .fit(&mut eng)
        .unwrap();
        for w in trace.windows(2) {
            assert!(
                w[1].objective >= w[0].objective - 1e-9,
                "objective decreased: {} -> {}",
                w[0].objective,
                w[1].objective
            );
        }
    }

    #[test]
    fn solution_is_feasible() {
        let mut eng = InMemoryPass::new(dataset(400, 48, 5));
        let lambda = 0.05;
        let (model, _) = Horst::new(HorstConfig {
            k: 4,
            lambda_a: lambda,
            lambda_b: lambda,
            pass_budget: 30,
            ..Default::default()
        })
        .fit(&mut eng)
        .unwrap();
        let f = feasibility(&model, &mut eng, lambda, lambda);
        assert!(f.cov_a_err < 1e-8, "{}", f.cov_a_err);
        assert!(f.cov_b_err < 1e-8);
        assert!(f.cross_offdiag < 1e-8);
    }

    #[test]
    fn rcca_init_converges_faster() {
        // Table 2b's Horst+rcca claim: warm starting from RandomizedCCA
        // reaches a target objective in fewer passes than cold start.
        let chunk = dataset(800, 96, 6);
        let lambda = 0.05;

        // Cold-start trace.
        let mut eng_cold = InMemoryPass::new(chunk.clone());
        let (model_cold, trace_cold) = Horst::new(HorstConfig {
            k: 5,
            lambda_a: lambda,
            lambda_b: lambda,
            pass_budget: 60,
            seed: 7,
            ..Default::default()
        })
        .fit(&mut eng_cold)
        .unwrap();
        let target = model_cold.sum_correlations() * 0.999;

        // Warm start from rcca (q=1).
        let mut eng_warm = InMemoryPass::new(chunk);
        let rcca = RandomizedCca::new(RccaConfig {
            k: 5,
            p: 40,
            q: 1,
            lambda_a: lambda,
            lambda_b: lambda,
            seed: 8,
        })
        .fit(&mut eng_warm)
        .unwrap();
        let init_passes = eng_warm.passes();
        let (_, trace_warm) = Horst::new(HorstConfig {
            k: 5,
            lambda_a: lambda,
            lambda_b: lambda,
            pass_budget: 60,
            seed: 9,
            ..Default::default()
        })
        .fit_from(&mut eng_warm, rcca.xa.clone(), rcca.xb.clone())
        .unwrap();

        let passes_cold = trace_cold
            .iter()
            .find(|t| t.objective >= target)
            .map(|t| t.passes)
            .unwrap_or(usize::MAX);
        let passes_warm = trace_warm
            .iter()
            .find(|t| t.objective >= target)
            .map(|t| t.passes + init_passes)
            .unwrap_or(usize::MAX);
        assert!(
            passes_warm <= passes_cold,
            "warm {passes_warm} cold {passes_cold}"
        );
    }

    #[test]
    fn early_stopping_triggers() {
        let mut eng = InMemoryPass::new(dataset(300, 48, 10));
        let (_, trace) = Horst::new(HorstConfig {
            k: 3,
            pass_budget: 400,
            lambda_a: 0.1,
            lambda_b: 0.1,
            tol: 1e-3,
            ..Default::default()
        })
        .fit(&mut eng)
        .unwrap();
        assert!(
            trace.last().unwrap().passes < 400,
            "should stop early, used {}",
            trace.last().unwrap().passes
        );
    }

    #[test]
    fn wide_augmented_basis_is_capped_not_a_panic() {
        // k = 12 on d = 24: the augmented basis (Y | X | precond·Y) reaches
        // 36 columns — wider than the view dimension. Must fit cleanly.
        let mut eng = InMemoryPass::new(dataset(300, 24, 13));
        let (model, trace) = Horst::new(HorstConfig {
            k: 12,
            lambda_a: 0.1,
            lambda_b: 0.1,
            pass_budget: 12,
            ..Default::default()
        })
        .fit(&mut eng)
        .unwrap();
        assert_eq!(model.k(), 12);
        assert!(trace.len() >= 3, "capping must not stop iteration");
    }

    #[test]
    fn budget_too_small_is_an_error() {
        let mut eng = InMemoryPass::new(dataset(100, 32, 11));
        let r = Horst::new(HorstConfig {
            k: 2,
            pass_budget: 1,
            ..Default::default()
        })
        .fit(&mut eng);
        assert!(r.is_err());
    }

    #[test]
    fn objective_agrees_with_evaluate() {
        let mut eng = InMemoryPass::new(dataset(300, 48, 12));
        let (model, _) = Horst::new(HorstConfig {
            k: 3,
            pass_budget: 20,
            lambda_a: 0.05,
            lambda_b: 0.05,
            ..Default::default()
        })
        .fit(&mut eng)
        .unwrap();
        let obj = evaluate(&model, &mut eng);
        assert!((obj.sum_corr - model.sum_correlations()).abs() < 1e-8);
    }
}
