//! The data-pass abstraction and its in-memory implementation.

use crate::data::TwoViewChunk;
use crate::linalg::{matmul_tn, Mat};
use crate::runtime::{ChunkEngine, ChunkMirror, NativeEngine, Workspace};
use std::sync::OnceLock;

/// One logical sweep over the two-view dataset, producing batched matrix
/// products. Every method that touches the data increments the pass ledger
/// by exactly one — the experiments report pass counts, mirroring the
/// paper's accounting ("as few as two data passes").
pub trait PassEngine {
    /// (n, da, db).
    fn dims(&self) -> (usize, usize, usize);

    /// Range-finder pass (Algorithm 1 lines 6–9):
    /// `Ya = Aᵀ(B·Qb)`, `Yb = Bᵀ(A·Qa)` — one pass.
    fn power_pass(&mut self, qa: &Mat, qb: &Mat) -> (Mat, Mat);

    /// Final-optimization pass (Algorithm 1 lines 14–18):
    /// `Ca = QaᵀAᵀAQa`, `Cb = QbᵀBᵀBQb`, `F = QaᵀAᵀBQb` — one pass.
    fn final_pass(&mut self, qa: &Mat, qb: &Mat) -> (Mat, Mat, Mat);

    /// `tr(AᵀA)` and `tr(BᵀB)` for the scale-free λ parameterization.
    /// Cheap enough to piggyback on any pass; implementations may cache it
    /// (it does not count as an extra pass when cached).
    fn gram_traces(&mut self) -> (f64, f64);

    /// Total data passes consumed so far.
    fn passes(&self) -> usize;

    /// Escape hatch for engine-specific plumbing behind `dyn PassEngine`
    /// (the cluster driver's merged-trace export). Default: not castable.
    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }
}

/// Single-node in-core implementation over CSR views.
///
/// The power pass runs on the same panel-blocked [`NativeEngine`] path the
/// coordinator uses, with a persistent [`Workspace`] (zero steady-state
/// allocations beyond the returned matrices) and a transposed mirror built
/// lazily on the first power pass — in-memory data is cached by
/// definition, so the transpose always amortizes.
pub struct InMemoryPass {
    /// Private: the lazily built mirror is the transpose of THIS data, so
    /// the dataset must not be swapped out from under it.
    chunk: TwoViewChunk,
    engine: NativeEngine,
    ws: Workspace,
    mirror: OnceLock<Option<ChunkMirror>>,
    passes: usize,
    traces: Option<(f64, f64)>,
}

impl InMemoryPass {
    pub fn new(chunk: TwoViewChunk) -> InMemoryPass {
        InMemoryPass {
            chunk,
            engine: NativeEngine::new(),
            ws: Workspace::new(),
            mirror: OnceLock::new(),
            passes: 0,
            traces: None,
        }
    }

    /// The dataset this engine sweeps (read-only — see the field docs).
    pub fn chunk(&self) -> &TwoViewChunk {
        &self.chunk
    }
}

impl PassEngine for InMemoryPass {
    fn dims(&self) -> (usize, usize, usize) {
        (self.chunk.rows(), self.chunk.a.cols, self.chunk.b.cols)
    }

    fn power_pass(&mut self, qa: &Mat, qb: &Mat) -> (Mat, Mat) {
        self.passes += 1;
        let r = qa.cols;
        assert_eq!(qb.cols, r, "Qa/Qb column mismatch");
        let qa32 = qa.to_f32();
        let qb32 = qb.to_f32();
        self.ws.begin_power(self.chunk.a.cols, self.chunk.b.cols, r);
        let mirror = self
            .mirror
            .get_or_init(|| ChunkMirror::maybe_build(&self.chunk))
            .as_ref();
        self.engine
            .power_chunk_ws(self.chunk.view(), mirror, &qa32, &qb32, r, &mut self.ws)
            .expect("in-memory power pass");
        let mut out = self.ws.take();
        let yb = out.pop().unwrap();
        let ya = out.pop().unwrap();
        (ya, yb)
    }

    fn final_pass(&mut self, qa: &Mat, qb: &Mat) -> (Mat, Mat, Mat) {
        self.passes += 1;
        // Deliberately NOT the f32 chunk-engine path: the final pass runs
        // once per fit, and computing whole-dataset Grams in f32 would
        // accumulate O(n) rounding that the sharded engine bounds per
        // chunk. Leader-side f64 keeps the exact-solver comparisons tight.
        let (a, b) = (&self.chunk.a, &self.chunk.b);
        let pa = a.times_mat(qa); // n × r
        let pb = b.times_mat(qb);
        let ca = matmul_tn(&pa, &pa);
        let cb = matmul_tn(&pb, &pb);
        let f = matmul_tn(&pa, &pb);
        (ca, cb, f)
    }

    fn gram_traces(&mut self) -> (f64, f64) {
        if let Some(t) = self.traces {
            return t;
        }
        // Counted as a pass the first time (it reads all values); real
        // deployments fold this into shard-writing statistics.
        self.passes += 1;
        let t = (self.chunk.a.gram_trace(), self.chunk.b.gram_trace());
        self.traces = Some(t);
        t
    }

    fn passes(&self) -> usize {
        self.passes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthparl::{SynthParl, SynthParlConfig};
    use crate::linalg::gemm::matmul;
    use crate::util::rng::Rng;

    fn tiny() -> TwoViewChunk {
        let d = SynthParl::generate(SynthParlConfig {
            n: 200,
            dims: 48,
            topics: 4,
            words_per_topic: 8,
            background_words: 16,
            mean_len: 6.0,
            seed: 71,
            ..Default::default()
        });
        TwoViewChunk { a: d.a, b: d.b }
    }

    #[test]
    fn power_pass_matches_dense_math() {
        let chunk = tiny();
        let (da_dense, db_dense) = (chunk.a.to_dense(), chunk.b.to_dense());
        let mut eng = InMemoryPass::new(chunk);
        let mut rng = Rng::new(1);
        let qa = Mat::randn(48, 5, &mut rng);
        let qb = Mat::randn(48, 5, &mut rng);
        let (ya, yb) = eng.power_pass(&qa, &qb);
        let want_ya = matmul_tn(&da_dense, &matmul(&db_dense, &qb));
        let want_yb = matmul_tn(&db_dense, &matmul(&da_dense, &qa));
        assert!(ya.rel_diff(&want_ya) < 1e-5);
        assert!(yb.rel_diff(&want_yb) < 1e-5);
        assert_eq!(eng.passes(), 1);
    }

    #[test]
    fn final_pass_matches_dense_math() {
        let chunk = tiny();
        let (da_dense, db_dense) = (chunk.a.to_dense(), chunk.b.to_dense());
        let mut eng = InMemoryPass::new(chunk);
        let mut rng = Rng::new(2);
        let qa = Mat::randn(48, 4, &mut rng);
        let qb = Mat::randn(48, 4, &mut rng);
        let (ca, cb, f) = eng.final_pass(&qa, &qb);
        let pa = matmul(&da_dense, &qa);
        let pb = matmul(&db_dense, &qb);
        assert!(ca.rel_diff(&matmul_tn(&pa, &pa)) < 1e-5);
        assert!(cb.rel_diff(&matmul_tn(&pb, &pb)) < 1e-5);
        assert!(f.rel_diff(&matmul_tn(&pa, &pb)) < 1e-5);
    }

    #[test]
    fn pass_ledger_counts_each_sweep() {
        let mut eng = InMemoryPass::new(tiny());
        let mut rng = Rng::new(3);
        let q = Mat::randn(48, 3, &mut rng);
        assert_eq!(eng.passes(), 0);
        eng.power_pass(&q, &q);
        eng.power_pass(&q, &q);
        eng.final_pass(&q, &q);
        assert_eq!(eng.passes(), 3);
        eng.gram_traces();
        assert_eq!(eng.passes(), 4);
        eng.gram_traces(); // cached — no extra pass
        assert_eq!(eng.passes(), 4);
    }

    #[test]
    fn gram_traces_match_dense() {
        let chunk = tiny();
        let dense_a = chunk.a.to_dense();
        let mut eng = InMemoryPass::new(chunk);
        let (ta, _tb) = eng.gram_traces();
        let want = matmul_tn(&dense_a, &dense_a).trace();
        assert!((ta - want).abs() / want < 1e-5);
    }
}
