//! Unified telemetry: structured tracing spans, a per-thread flight
//! recorder, and one metrics pipeline shared by fit, serve, cluster, and
//! lifecycle.
//!
//! Two halves:
//!
//! * [`recorder`] — `Span`/`Event` records with ids, parent links, wall +
//!   thread-CPU timing, and typed attributes, buffered in lock-light
//!   per-thread rings and exported as JSONL (`repro trace` pretty-prints
//!   them via [`trace`]). Instrumentation is always compiled in and costs
//!   one atomic load when the recorder is off; `--trace <file>` on the
//!   fit/daemon CLIs turns it on.
//! * [`registry`] — [`MetricsRegistry`], which absorbs the pre-existing
//!   counter silos (`coordinator::Metrics`, `serve::ServeMetrics`, the
//!   lifecycle daemon's counters) behind one registration API and renders
//!   both the legacy JSON shapes (byte-compatible) and Prometheus text
//!   format (`GET /metrics?format=prom`).
//!
//! Span vocabulary used across the system (names are stable — CI greps
//! them): `fit` (api), `pass`/`shard_task`/`load`/`decode`/`engine`/
//! `reduce` (coordinator), `round` (cluster driver and worker — since the
//! distributed-tracing PR the worker's `round` is a *true child* of the
//! driver's, linked by the `TraceCtx` carried in the wire protocol, and
//! both are tagged with a `worker` attr: `"driver"` or the worker's
//! address), `request`/`parse`/`handle`/`write` (serve), `tick`/`refit`
//! (lifecycle daemon, linked to the audit ledger via the `episode` attr).
//! Cluster lifecycle events (`cluster.join`, `cluster.death`,
//! `cluster.redispatch`, `cluster.checkpoint`, `cluster.resume`,
//! `cluster.mirror`, `cluster.chaos`, `cluster.straggler`) appear in the
//! merged timeline as instantaneous events.

pub mod recorder;
pub mod registry;
pub mod trace;

pub use recorder::{
    disable, drain, enabled, event, export_jsonl, install, install_default, install_with_base,
    now_ns, record_manual, span, span_child_of, AttrValue, RecordKind, Span, SpanRecord, Trace,
    DEFAULT_CAPACITY,
};
pub use registry::{
    counter, counter_vec, gauge, gauge_vec, histogram, histogram_vec, parse_prom,
    render_families, Family, FamilyKind, HistogramSnapshot, MetricSource, MetricsRegistry, Sample,
};
