//! Reading exported JSONL traces back: filter, and render a span tree with
//! wall/CPU timings — the library half of the `repro trace` CLI.

use crate::util::json::{parse, Json};
use std::path::Path;

/// One span/event parsed back from a JSONL trace line.
#[derive(Debug, Clone)]
pub struct TraceSpan {
    pub kind: String,
    pub id: u64,
    pub parent: u64,
    pub name: String,
    pub thread: u64,
    pub start_ns: u64,
    pub wall_ns: u64,
    pub cpu_ns: u64,
    pub attrs: Json,
}

/// A parsed trace file: spans in file order plus the footer's drop count.
#[derive(Debug)]
pub struct TraceFile {
    pub spans: Vec<TraceSpan>,
    pub dropped: u64,
}

/// Read a JSONL trace written by `recorder::export_jsonl`. Unknown kinds
/// are an error (fail closed, same policy as the audit ledger) so a
/// corrupted or foreign file is reported instead of half-rendered.
pub fn read_jsonl(path: &Path) -> Result<TraceFile, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read trace {}: {e}", path.display()))?;
    let mut spans = Vec::new();
    let mut dropped = 0u64;
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let doc = parse(line).map_err(|e| format!("trace line {}: {e}", i + 1))?;
        let kind = doc
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or_else(|| format!("trace line {}: missing kind", i + 1))?;
        match kind {
            "span" | "event" => {
                let num = |key: &str| -> Result<u64, String> {
                    doc.get(key)
                        .and_then(|v| v.as_f64())
                        .map(|v| v as u64)
                        .ok_or_else(|| format!("trace line {}: missing {key}", i + 1))
                };
                spans.push(TraceSpan {
                    kind: kind.to_string(),
                    id: num("id")?,
                    parent: num("parent")?,
                    name: doc
                        .get("name")
                        .and_then(|n| n.as_str())
                        .ok_or_else(|| format!("trace line {}: missing name", i + 1))?
                        .to_string(),
                    thread: num("thread")?,
                    start_ns: num("start_ns")?,
                    wall_ns: num("wall_ns")?,
                    cpu_ns: num("cpu_ns")?,
                    attrs: doc.get("attrs").cloned().unwrap_or_else(Json::obj),
                });
            }
            "trace" => {
                dropped = doc.get("dropped").and_then(|d| d.as_f64()).unwrap_or(0.0) as u64;
            }
            other => return Err(format!("trace line {}: unknown kind {other:?}", i + 1)),
        }
    }
    Ok(TraceFile { spans, dropped })
}

fn fmt_ns(ns: u64) -> String {
    let s = ns as f64 / 1e9;
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

fn render_line(s: &TraceSpan, depth: usize, out: &mut String) {
    for _ in 0..depth {
        out.push_str("  ");
    }
    if s.kind == "event" {
        out.push_str(&format!("! {} [{}]", s.name, s.id));
    } else {
        out.push_str(&format!(
            "{} [{}] wall={} cpu={}",
            s.name,
            s.id,
            fmt_ns(s.wall_ns),
            fmt_ns(s.cpu_ns)
        ));
    }
    out.push_str(&format!(" t{}", s.thread));
    if !matches!(&s.attrs, Json::Obj(m) if m.is_empty()) {
        out.push(' ');
        out.push_str(&s.attrs.to_string_compact());
    }
    out.push('\n');
}

/// Render the selected spans as an indented tree (start-time order within
/// each level). `last` keeps only the N most recent spans (0 = all);
/// `name_filter` keeps spans whose name contains the substring, plus all
/// their ancestors so the tree stays connected.
pub fn render_tree(trace: &TraceFile, last: usize, name_filter: Option<&str>) -> String {
    let mut spans: Vec<&TraceSpan> = trace.spans.iter().collect();
    spans.sort_by_key(|s| (s.start_ns, s.id));
    if let Some(pat) = name_filter {
        let by_id: std::collections::BTreeMap<u64, &TraceSpan> =
            spans.iter().map(|s| (s.id, *s)).collect();
        let mut keep = std::collections::BTreeSet::new();
        for s in &spans {
            if s.name.contains(pat) {
                // Keep the match and walk its ancestry to the root.
                let mut cur = Some(*s);
                while let Some(c) = cur {
                    if !keep.insert(c.id) {
                        break;
                    }
                    cur = by_id.get(&c.parent).copied();
                }
            }
        }
        spans.retain(|s| keep.contains(&s.id));
    }
    if last > 0 && spans.len() > last {
        let cut = spans.len() - last;
        spans.drain(..cut);
    }
    let ids: std::collections::BTreeSet<u64> = spans.iter().map(|s| s.id).collect();
    let mut children: std::collections::BTreeMap<u64, Vec<&TraceSpan>> =
        std::collections::BTreeMap::new();
    let mut roots: Vec<&TraceSpan> = Vec::new();
    for s in &spans {
        if s.parent != 0 && ids.contains(&s.parent) {
            children.entry(s.parent).or_default().push(s);
        } else {
            roots.push(s);
        }
    }
    let mut out = String::new();
    // Iterative DFS to keep arbitrarily deep traces off the call stack.
    let mut stack: Vec<(&TraceSpan, usize)> = roots.into_iter().rev().map(|s| (s, 0)).collect();
    while let Some((s, depth)) = stack.pop() {
        render_line(s, depth, &mut out);
        if let Some(kids) = children.get(&s.id) {
            for k in kids.iter().rev() {
                stack.push((k, depth + 1));
            }
        }
    }
    if trace.dropped > 0 {
        out.push_str(&format!(
            "({} older spans dropped by the flight recorder ring)\n",
            trace.dropped
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::recorder::{RecordKind, SpanRecord, Trace};

    fn rec(id: u64, parent: u64, name: &'static str, start_ns: u64) -> SpanRecord {
        SpanRecord {
            kind: RecordKind::Span,
            id,
            parent,
            name,
            thread: 1,
            start_ns,
            wall_ns: 10,
            cpu_ns: 8,
            attrs: vec![("shard", 2usize.into())],
        }
    }

    #[test]
    fn written_trace_reads_back_and_renders() {
        let dir = std::env::temp_dir().join("rcca_telemetry_trace_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("t.jsonl");
        let trace = Trace {
            spans: vec![rec(1, 0, "fit", 0), rec(2, 1, "pass", 1), rec(3, 2, "shard_task", 2)],
            dropped: 4,
        };
        trace.write_jsonl(&path).unwrap();
        let back = read_jsonl(&path).unwrap();
        assert_eq!(back.spans.len(), 3);
        assert_eq!(back.dropped, 4);
        let tree = render_tree(&back, 0, None);
        let fit_at = tree.find("fit [1]").unwrap();
        let pass_at = tree.find("  pass [2]").unwrap();
        let task_at = tree.find("    shard_task [3]").unwrap();
        assert!(fit_at < pass_at && pass_at < task_at, "{tree}");
        assert!(tree.contains("4 older spans dropped"), "{tree}");
        // Name filtering keeps ancestors so the tree stays rooted.
        let filtered = render_tree(&back, 0, Some("shard"));
        assert!(filtered.contains("fit [1]"), "{filtered}");
        assert!(filtered.contains("shard_task [3]"), "{filtered}");
        assert!(!filtered.contains("\"pass\""), "{filtered}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_kind_fails_closed() {
        let dir = std::env::temp_dir().join("rcca_telemetry_trace_bad");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"kind\":\"mystery\"}\n").unwrap();
        let err = read_jsonl(&path).unwrap_err();
        assert!(err.contains("unknown kind"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
