//! Reading exported JSONL traces back: filter, render a span tree with
//! wall/CPU timings, and analyze merged cross-process cluster timelines
//! (critical-path attribution, straggler detection) — the library half of
//! the `repro trace` CLI and of the driver's merged `--trace` export.

use crate::util::json::{jstr, parse, Json};
use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::path::Path;

/// One span/event parsed back from a JSONL trace line.
#[derive(Debug, Clone)]
pub struct TraceSpan {
    pub kind: String,
    pub id: u64,
    pub parent: u64,
    pub name: String,
    pub thread: u64,
    pub start_ns: u64,
    pub wall_ns: u64,
    pub cpu_ns: u64,
    pub attrs: Json,
}

impl TraceSpan {
    /// One JSONL line in the same fixed field order as
    /// [`crate::telemetry::SpanRecord::to_jsonl`]. Unlike the recorder's
    /// `&'static` names, merged-trace names crossed a wire, so they are
    /// escaped as real JSON strings.
    pub fn to_jsonl(&self) -> String {
        format!(
            "{{\"kind\":{},\"id\":{},\"parent\":{},\"name\":{},\"thread\":{},\
             \"start_ns\":{},\"wall_ns\":{},\"cpu_ns\":{},\"attrs\":{}}}",
            jstr(&self.kind).to_string_compact(),
            self.id,
            self.parent,
            jstr(&self.name).to_string_compact(),
            self.thread,
            self.start_ns,
            self.wall_ns,
            self.cpu_ns,
            self.attrs.to_string_compact()
        )
    }

    fn attr_str(&self, key: &str) -> Option<&str> {
        self.attrs.get(key).and_then(|v| v.as_str())
    }
}

impl From<&crate::telemetry::SpanRecord> for TraceSpan {
    fn from(rec: &crate::telemetry::SpanRecord) -> TraceSpan {
        let mut attrs = Json::obj();
        for (k, v) in &rec.attrs {
            attrs.set(k, v.to_json());
        }
        TraceSpan {
            kind: match rec.kind {
                crate::telemetry::RecordKind::Span => "span".to_string(),
                crate::telemetry::RecordKind::Event => "event".to_string(),
            },
            id: rec.id,
            parent: rec.parent,
            name: rec.name.to_string(),
            thread: rec.thread,
            start_ns: rec.start_ns,
            wall_ns: rec.wall_ns,
            cpu_ns: rec.cpu_ns,
            attrs,
        }
    }
}

/// Shift a batch of spans from a remote clock onto the local timeline:
/// `skew_ns` is (remote monotonic − local monotonic), estimated from the
/// RunPass send/receive handshake, so subtracting it re-expresses remote
/// start times on the driver's clock (clamped at 0).
pub fn apply_skew(spans: &mut [TraceSpan], skew_ns: i64) {
    for s in spans.iter_mut() {
        s.start_ns = (s.start_ns as i64 - skew_ns).max(0) as u64;
    }
}

/// Write one merged JSONL trace: spans sorted by corrected start time, the
/// same footer contract as `recorder::Trace::write_jsonl` (the drop count
/// here totals local and every shipped worker batch).
pub fn write_merged_jsonl(
    path: &Path,
    spans: &mut Vec<TraceSpan>,
    dropped: u64,
) -> std::io::Result<()> {
    spans.sort_by_key(|s| (s.start_ns, s.id));
    let mut out = String::new();
    for s in spans.iter() {
        out.push_str(&s.to_jsonl());
        out.push('\n');
    }
    out.push_str(&format!(
        "{{\"kind\":\"trace\",\"spans\":{},\"dropped\":{}}}\n",
        spans.len(),
        dropped
    ));
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(out.as_bytes())
}

/// A parsed trace file: spans in file order plus the footer's drop count.
#[derive(Debug)]
pub struct TraceFile {
    pub spans: Vec<TraceSpan>,
    pub dropped: u64,
}

/// Read a JSONL trace written by `recorder::export_jsonl`. Unknown kinds
/// are an error (fail closed, same policy as the audit ledger) so a
/// corrupted or foreign file is reported instead of half-rendered.
pub fn read_jsonl(path: &Path) -> Result<TraceFile, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read trace {}: {e}", path.display()))?;
    let mut spans = Vec::new();
    let mut dropped = 0u64;
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let doc = parse(line).map_err(|e| format!("trace line {}: {e}", i + 1))?;
        let kind = doc
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or_else(|| format!("trace line {}: missing kind", i + 1))?;
        match kind {
            "span" | "event" => {
                let num = |key: &str| -> Result<u64, String> {
                    doc.get(key)
                        .and_then(|v| v.as_f64())
                        .map(|v| v as u64)
                        .ok_or_else(|| format!("trace line {}: missing {key}", i + 1))
                };
                spans.push(TraceSpan {
                    kind: kind.to_string(),
                    id: num("id")?,
                    parent: num("parent")?,
                    name: doc
                        .get("name")
                        .and_then(|n| n.as_str())
                        .ok_or_else(|| format!("trace line {}: missing name", i + 1))?
                        .to_string(),
                    thread: num("thread")?,
                    start_ns: num("start_ns")?,
                    wall_ns: num("wall_ns")?,
                    cpu_ns: num("cpu_ns")?,
                    attrs: doc.get("attrs").cloned().unwrap_or_else(Json::obj),
                });
            }
            "trace" => {
                dropped = doc.get("dropped").and_then(|d| d.as_f64()).unwrap_or(0.0) as u64;
            }
            other => return Err(format!("trace line {}: unknown kind {other:?}", i + 1)),
        }
    }
    Ok(TraceFile { spans, dropped })
}

fn fmt_ns(ns: u64) -> String {
    let s = ns as f64 / 1e9;
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

fn render_line(s: &TraceSpan, depth: usize, out: &mut String) {
    for _ in 0..depth {
        out.push_str("  ");
    }
    if s.kind == "event" {
        out.push_str(&format!("! {} [{}]", s.name, s.id));
    } else {
        out.push_str(&format!(
            "{} [{}] wall={} cpu={}",
            s.name,
            s.id,
            fmt_ns(s.wall_ns),
            fmt_ns(s.cpu_ns)
        ));
    }
    out.push_str(&format!(" t{}", s.thread));
    if !matches!(&s.attrs, Json::Obj(m) if m.is_empty()) {
        out.push(' ');
        out.push_str(&s.attrs.to_string_compact());
    }
    out.push('\n');
}

/// Render the selected spans as an indented tree (start-time order within
/// each level). `last` keeps only the N most recent spans (0 = all);
/// `name_filter` keeps spans whose name contains the substring, plus all
/// their ancestors so the tree stays connected.
pub fn render_tree(trace: &TraceFile, last: usize, name_filter: Option<&str>) -> String {
    let mut spans: Vec<&TraceSpan> = trace.spans.iter().collect();
    spans.sort_by_key(|s| (s.start_ns, s.id));
    if let Some(pat) = name_filter {
        let by_id: std::collections::BTreeMap<u64, &TraceSpan> =
            spans.iter().map(|s| (s.id, *s)).collect();
        let mut keep = std::collections::BTreeSet::new();
        for s in &spans {
            if s.name.contains(pat) {
                // Keep the match and walk its ancestry to the root.
                let mut cur = Some(*s);
                while let Some(c) = cur {
                    if !keep.insert(c.id) {
                        break;
                    }
                    cur = by_id.get(&c.parent).copied();
                }
            }
        }
        spans.retain(|s| keep.contains(&s.id));
    }
    if last > 0 && spans.len() > last {
        let cut = spans.len() - last;
        spans.drain(..cut);
    }
    let ids: BTreeSet<u64> = spans.iter().map(|s| s.id).collect();
    let mut children: BTreeMap<u64, Vec<&TraceSpan>> = BTreeMap::new();
    let mut roots: Vec<&TraceSpan> = Vec::new();
    // Orphans — spans whose parent id is absent from the file (ring-evicted
    // or filtered away) — group under an explicit `<dropped ancestor>`
    // placeholder per missing id instead of silently re-rooting, so the
    // rendering never lies about parentage.
    let mut orphans: BTreeMap<u64, Vec<&TraceSpan>> = BTreeMap::new();
    for s in &spans {
        if s.parent == 0 {
            roots.push(s);
        } else if ids.contains(&s.parent) {
            children.entry(s.parent).or_default().push(s);
        } else {
            orphans.entry(s.parent).or_default().push(s);
        }
    }
    let mut out = String::new();
    // Iterative DFS to keep arbitrarily deep traces off the call stack.
    let mut stack: Vec<(&TraceSpan, usize)> = roots.into_iter().rev().map(|s| (s, 0)).collect();
    while let Some((s, depth)) = stack.pop() {
        render_line(s, depth, &mut out);
        if let Some(kids) = children.get(&s.id) {
            for k in kids.iter().rev() {
                stack.push((k, depth + 1));
            }
        }
    }
    for (missing, kids) in &orphans {
        out.push_str(&format!("<dropped ancestor> [{missing}]\n"));
        let mut stack: Vec<(&TraceSpan, usize)> =
            kids.iter().rev().map(|s| (*s, 1)).collect();
        while let Some((s, depth)) = stack.pop() {
            render_line(s, depth, &mut out);
            if let Some(kids) = children.get(&s.id) {
                for k in kids.iter().rev() {
                    stack.push((k, depth + 1));
                }
            }
        }
    }
    if trace.dropped > 0 {
        out.push_str(&format!(
            "({} older spans dropped by the flight recorder ring)\n",
            trace.dropped
        ));
    }
    out
}

fn pct(part: u64, whole: u64) -> f64 {
    if whole == 0 {
        0.0
    } else {
        part as f64 * 100.0 / whole as f64
    }
}

/// Wall time a worker's round spent in each category. The five categories
/// partition the *driver* round wall exactly: network and straggler-wait
/// are residuals, so `total()` always equals the driver round wall and the
/// attribution is 100% by construction (the ≥95% contract with margin).
#[derive(Debug, Clone, Default)]
pub struct RoundAttribution {
    pub worker: String,
    pub round_wall_ns: u64,
    pub compute_ns: u64,
    pub decode_ns: u64,
    pub io_ns: u64,
    pub network_ns: u64,
    pub straggler_wait_ns: u64,
}

impl RoundAttribution {
    pub fn total(&self) -> u64 {
        self.compute_ns + self.decode_ns + self.io_ns + self.network_ns + self.straggler_wait_ns
    }
}

/// One driver round with its per-worker attribution and critical path.
#[derive(Debug)]
pub struct RoundAnalysis {
    pub pass_id: u64,
    pub round_span: u64,
    pub wall_ns: u64,
    pub workers: Vec<RoundAttribution>,
    /// `name [id] wall` triples from the driver round down the slowest
    /// dependency chain.
    pub critical_path: Vec<(String, u64, u64)>,
}

fn children_map(trace: &TraceFile) -> BTreeMap<u64, Vec<&TraceSpan>> {
    let mut map: BTreeMap<u64, Vec<&TraceSpan>> = BTreeMap::new();
    for s in &trace.spans {
        if s.kind == "span" && s.parent != 0 {
            map.entry(s.parent).or_default().push(s);
        }
    }
    for kids in map.values_mut() {
        kids.sort_by_key(|s| (s.start_ns, s.id));
    }
    map
}

/// Sum `wall_ns` over every descendant of `root` named `name`.
fn subtree_sum(children: &BTreeMap<u64, Vec<&TraceSpan>>, root: u64, name: &str) -> u64 {
    let mut total = 0u64;
    let mut stack = vec![root];
    while let Some(id) = stack.pop() {
        if let Some(kids) = children.get(&id) {
            for k in kids {
                if k.name == name {
                    total += k.wall_ns;
                }
                stack.push(k.id);
            }
        }
    }
    total
}

/// Analyze a merged cluster trace: every driver `round` span (tagged
/// `worker="driver"`), its workers' child `round` spans, and the category
/// attribution of each worker's share of the round wall.
pub fn analyze_rounds(trace: &TraceFile) -> Vec<RoundAnalysis> {
    let children = children_map(trace);
    let mut out = Vec::new();
    let mut driver_rounds: Vec<&TraceSpan> = trace
        .spans
        .iter()
        .filter(|s| s.kind == "span" && s.name == "round" && s.attr_str("worker") == Some("driver"))
        .collect();
    driver_rounds.sort_by_key(|s| (s.start_ns, s.id));
    for round in driver_rounds {
        let pass_id = round
            .attrs
            .get("pass_id")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;
        let mut workers = Vec::new();
        let mut worker_rounds: Vec<&TraceSpan> = children
            .get(&round.id)
            .map(|kids| {
                kids.iter()
                    .filter(|k| k.name == "round")
                    .copied()
                    .collect()
            })
            .unwrap_or_default();
        worker_rounds.sort_by_key(|s| (s.start_ns, s.id));
        for wr in &worker_rounds {
            let compute = subtree_sum(&children, wr.id, "engine");
            let decode = subtree_sum(&children, wr.id, "decode");
            let io = subtree_sum(&children, wr.id, "load");
            let network = wr.wall_ns.saturating_sub(compute + decode + io);
            let straggler_wait = round.wall_ns.saturating_sub(wr.wall_ns);
            workers.push(RoundAttribution {
                worker: wr.attr_str("worker").unwrap_or("?").to_string(),
                round_wall_ns: wr.wall_ns,
                compute_ns: compute.min(wr.wall_ns),
                decode_ns: decode,
                io_ns: io,
                network_ns: network,
                straggler_wait_ns: straggler_wait,
            });
        }
        // Critical path: driver round → slowest worker round → its slowest
        // shard_task → that task's slowest stage.
        let mut critical_path = vec![("round".to_string(), round.id, round.wall_ns)];
        let mut cur = worker_rounds.iter().max_by_key(|w| w.wall_ns).copied();
        while let Some(node) = cur {
            critical_path.push((
                match node.attr_str("worker") {
                    Some(w) if node.name == "round" => format!("round@{w}"),
                    _ => node.name.clone(),
                },
                node.id,
                node.wall_ns,
            ));
            cur = children
                .get(&node.id)
                .and_then(|kids| kids.iter().max_by_key(|k| k.wall_ns).copied());
        }
        out.push(RoundAnalysis {
            pass_id,
            round_span: round.id,
            wall_ns: round.wall_ns,
            workers,
            critical_path,
        });
    }
    out
}

/// `repro trace --critical-path`: per-round, per-worker wall-time
/// attribution plus the longest dependency chain.
pub fn critical_path_report(trace: &TraceFile) -> String {
    let rounds = analyze_rounds(trace);
    if rounds.is_empty() {
        return "no cluster rounds in trace (need a merged --trace from a cluster fit)\n"
            .to_string();
    }
    let mut out = String::new();
    for r in &rounds {
        out.push_str(&format!(
            "pass {} round [{}] wall={}\n",
            r.pass_id,
            r.round_span,
            fmt_ns(r.wall_ns)
        ));
        for w in &r.workers {
            out.push_str(&format!(
                "  worker {:<22} wall={:<10} compute {:5.1}% | decode {:5.1}% | \
                 io-prefetch {:5.1}% | network {:5.1}% | straggler-wait {:5.1}% \
                 (attributed {:.1}%)\n",
                w.worker,
                fmt_ns(w.round_wall_ns),
                pct(w.compute_ns, r.wall_ns),
                pct(w.decode_ns, r.wall_ns),
                pct(w.io_ns, r.wall_ns),
                pct(w.network_ns, r.wall_ns),
                pct(w.straggler_wait_ns, r.wall_ns),
                pct(w.total(), r.wall_ns),
            ));
        }
        out.push_str("  critical path:");
        for (i, (name, id, wall)) in r.critical_path.iter().enumerate() {
            if i > 0 {
                out.push_str(" ->");
            }
            out.push_str(&format!(" {name} [{id}] {}", fmt_ns(*wall)));
        }
        out.push('\n');
    }
    out
}

/// Per-worker shard_task latency profile for straggler detection.
#[derive(Debug)]
pub struct WorkerLatency {
    pub worker: String,
    pub tasks: usize,
    pub p50_ns: u64,
    pub max_ns: u64,
    pub straggler: bool,
}

/// `repro trace --stragglers`: flag workers whose shard_task p50 exceeds
/// the fleet median by `factor`. The fleet median is the *lower* median of
/// per-worker p50s, so with two workers the slower one is compared against
/// the faster — a delayed worker in a 2-node fleet is still caught.
pub fn stragglers(trace: &TraceFile, factor: f64) -> Vec<WorkerLatency> {
    let children = children_map(trace);
    // shard_task spans belong to the worker named on their ancestor round.
    let mut per_worker: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    for s in &trace.spans {
        if s.kind == "span" && s.name == "round" {
            if let Some(worker) = s.attr_str("worker") {
                if worker == "driver" {
                    continue;
                }
                let mut stack = vec![s.id];
                while let Some(id) = stack.pop() {
                    if let Some(kids) = children.get(&id) {
                        for k in kids {
                            if k.name == "shard_task" {
                                per_worker
                                    .entry(worker.to_string())
                                    .or_default()
                                    .push(k.wall_ns);
                            }
                            stack.push(k.id);
                        }
                    }
                }
            }
        }
    }
    let mut latencies: Vec<WorkerLatency> = per_worker
        .into_iter()
        .map(|(worker, mut walls)| {
            walls.sort_unstable();
            let p50 = walls[(walls.len() - 1) / 2];
            WorkerLatency {
                worker,
                tasks: walls.len(),
                p50_ns: p50,
                max_ns: *walls.last().unwrap(),
                straggler: false,
            }
        })
        .collect();
    if latencies.is_empty() {
        return latencies;
    }
    let mut p50s: Vec<u64> = latencies.iter().map(|l| l.p50_ns).collect();
    p50s.sort_unstable();
    let fleet_median = p50s[(p50s.len() - 1) / 2];
    for l in latencies.iter_mut() {
        l.straggler = l.p50_ns as f64 > fleet_median as f64 * factor;
    }
    latencies
}

/// Render [`stragglers`] as the `--stragglers` report; the second return
/// is the flagged worker list (what the CI smoke asserts on).
pub fn stragglers_report(trace: &TraceFile, factor: f64) -> (String, Vec<String>) {
    let latencies = stragglers(trace, factor);
    if latencies.is_empty() {
        return (
            "no worker shard_task spans in trace (need a merged --trace from a cluster fit)\n"
                .to_string(),
            Vec::new(),
        );
    }
    let mut out = String::new();
    let mut flagged = Vec::new();
    out.push_str(&format!("straggler factor: {factor}\n"));
    for l in &latencies {
        out.push_str(&format!(
            "worker {:<22} tasks={:<4} p50={:<10} max={:<10}{}\n",
            l.worker,
            l.tasks,
            fmt_ns(l.p50_ns),
            fmt_ns(l.max_ns),
            if l.straggler { " STRAGGLER" } else { "" }
        ));
        if l.straggler {
            flagged.push(l.worker.clone());
        }
    }
    if flagged.is_empty() {
        out.push_str("no stragglers\n");
    } else {
        out.push_str(&format!("stragglers: {}\n", flagged.join(", ")));
    }
    (out, flagged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::recorder::{RecordKind, SpanRecord, Trace};

    fn rec(id: u64, parent: u64, name: &'static str, start_ns: u64) -> SpanRecord {
        SpanRecord {
            kind: RecordKind::Span,
            id,
            parent,
            name,
            thread: 1,
            start_ns,
            wall_ns: 10,
            cpu_ns: 8,
            attrs: vec![("shard", 2usize.into())],
        }
    }

    #[test]
    fn written_trace_reads_back_and_renders() {
        let dir = std::env::temp_dir().join("rcca_telemetry_trace_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("t.jsonl");
        let trace = Trace {
            spans: vec![rec(1, 0, "fit", 0), rec(2, 1, "pass", 1), rec(3, 2, "shard_task", 2)],
            dropped: 4,
        };
        trace.write_jsonl(&path).unwrap();
        let back = read_jsonl(&path).unwrap();
        assert_eq!(back.spans.len(), 3);
        assert_eq!(back.dropped, 4);
        let tree = render_tree(&back, 0, None);
        let fit_at = tree.find("fit [1]").unwrap();
        let pass_at = tree.find("  pass [2]").unwrap();
        let task_at = tree.find("    shard_task [3]").unwrap();
        assert!(fit_at < pass_at && pass_at < task_at, "{tree}");
        assert!(tree.contains("4 older spans dropped"), "{tree}");
        // Name filtering keeps ancestors so the tree stays rooted.
        let filtered = render_tree(&back, 0, Some("shard"));
        assert!(filtered.contains("fit [1]"), "{filtered}");
        assert!(filtered.contains("shard_task [3]"), "{filtered}");
        assert!(!filtered.contains("\"pass\""), "{filtered}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Spans whose parent id is absent must group under an explicit
    /// `<dropped ancestor>` placeholder, not silently re-root.
    #[test]
    fn missing_parents_get_a_dropped_ancestor_placeholder() {
        let trace = TraceFile {
            spans: vec![
                TraceSpan::from(&rec(1, 0, "fit", 0)),
                // Parent 99 was ring-evicted and is not in the file.
                TraceSpan::from(&rec(7, 99, "shard_task", 5)),
                TraceSpan::from(&rec(8, 7, "engine", 6)),
            ],
            dropped: 0,
        };
        let tree = render_tree(&trace, 0, None);
        assert!(tree.contains("<dropped ancestor> [99]"), "{tree}");
        let placeholder_at = tree.find("<dropped ancestor> [99]").unwrap();
        let task_at = tree.find("  shard_task [7]").unwrap();
        let engine_at = tree.find("    engine [8]").unwrap();
        assert!(placeholder_at < task_at && task_at < engine_at, "{tree}");
        // The true root is untouched.
        assert!(tree.contains("fit [1]"), "{tree}");
    }

    /// Clock-skew correction is pure arithmetic: given fixed handshake
    /// timestamps the merged timeline is deterministic.
    #[test]
    fn skew_correction_is_deterministic() {
        let mk = |id, start| TraceSpan {
            kind: "span".to_string(),
            id,
            parent: 0,
            name: "round".to_string(),
            thread: 1,
            start_ns: start,
            wall_ns: 10,
            cpu_ns: 0,
            attrs: Json::obj(),
        };
        // Worker clock runs 1500ns ahead of the driver's.
        let mut remote = vec![mk(2, 2000), mk(3, 1000)];
        apply_skew(&mut remote, 1500);
        assert_eq!(remote[0].start_ns, 500);
        assert_eq!(remote[1].start_ns, 0, "clamped at the epoch, never wraps");
        // A worker clock *behind* the driver's shifts forward.
        let mut behind = vec![mk(4, 100)];
        apply_skew(&mut behind, -400);
        assert_eq!(behind[0].start_ns, 500);
        // Merged output is sorted by corrected start, bitwise-stable.
        let dir = std::env::temp_dir().join("rcca_trace_skew_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("merged.jsonl");
        let mut all: Vec<TraceSpan> = remote.into_iter().chain(behind).collect();
        write_merged_jsonl(&path, &mut all, 3).unwrap();
        let first = std::fs::read(&path).unwrap();
        let mut again: Vec<TraceSpan> = all.clone();
        write_merged_jsonl(&path, &mut again, 3).unwrap();
        assert_eq!(first, std::fs::read(&path).unwrap());
        let back = read_jsonl(&path).unwrap();
        assert_eq!(back.dropped, 3);
        let starts: Vec<u64> = back.spans.iter().map(|s| s.start_ns).collect();
        assert_eq!(starts, vec![0, 500, 500], "sorted by corrected start");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Build a synthetic 2-worker merged round and check both analyses:
    /// category attribution partitions the driver round wall, and the
    /// delayed worker is flagged as the straggler.
    #[test]
    fn critical_path_and_stragglers_on_a_synthetic_round() {
        let span = |id, parent, name: &str, start, wall, worker: Option<&str>| {
            let mut attrs = Json::obj();
            if let Some(w) = worker {
                attrs.set("worker", jstr(w));
            }
            if name == "round" && worker == Some("driver") {
                attrs.set("pass_id", Json::Num(1.0));
            }
            TraceSpan {
                kind: "span".to_string(),
                id,
                parent,
                name: name.to_string(),
                thread: 1,
                start_ns: start,
                wall_ns: wall,
                cpu_ns: 0,
                attrs,
            }
        };
        let trace = TraceFile {
            spans: vec![
                span(1, 0, "round", 0, 1000, Some("driver")),
                // Fast worker: 400ns round, one task (engine 150 + load 50).
                span(10, 1, "round", 10, 400, Some("127.0.0.1:7001")),
                span(11, 10, "shard_task", 20, 250, None),
                span(12, 11, "load", 20, 50, None),
                span(13, 11, "engine", 80, 150, None),
                // Slow worker: 900ns round, delayed task.
                span(20, 1, "round", 10, 900, Some("127.0.0.1:7002")),
                span(21, 20, "shard_task", 20, 850, None),
                span(22, 21, "load", 20, 60, None),
                span(23, 21, "decode", 90, 40, None),
                span(24, 21, "engine", 140, 200, None),
            ],
            dropped: 0,
        };
        let rounds = analyze_rounds(&trace);
        assert_eq!(rounds.len(), 1);
        let r = &rounds[0];
        assert_eq!(r.pass_id, 1);
        assert_eq!(r.wall_ns, 1000);
        assert_eq!(r.workers.len(), 2);
        for w in &r.workers {
            assert_eq!(
                w.total(),
                r.wall_ns,
                "categories must partition the driver round wall for {}",
                w.worker
            );
        }
        let slow = r.workers.iter().find(|w| w.worker.ends_with("7002")).unwrap();
        assert_eq!(slow.compute_ns, 200);
        assert_eq!(slow.decode_ns, 40);
        assert_eq!(slow.io_ns, 60);
        assert_eq!(slow.straggler_wait_ns, 100, "1000 - 900");
        assert_eq!(slow.network_ns, 600, "900 - (200+40+60)");
        // The critical path runs through the slow worker.
        let chain: Vec<&str> = r.critical_path.iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(chain, vec!["round", "round@127.0.0.1:7002", "shard_task", "engine"]);
        let report = critical_path_report(&trace);
        assert!(report.contains("attributed 100.0%"), "{report}");
        // Straggler detection: slow p50 850 > 2.0 x fast p50 250.
        let (sreport, flagged) = stragglers_report(&trace, 2.0);
        assert_eq!(flagged, vec!["127.0.0.1:7002".to_string()], "{sreport}");
        assert!(sreport.contains("STRAGGLER"), "{sreport}");
        // A forgiving factor flags nobody.
        let (_, none) = stragglers_report(&trace, 4.0);
        assert!(none.is_empty());
    }

    #[test]
    fn unknown_kind_fails_closed() {
        let dir = std::env::temp_dir().join("rcca_telemetry_trace_bad");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"kind\":\"mystery\"}\n").unwrap();
        let err = read_jsonl(&path).unwrap_err();
        assert!(err.contains("unknown kind"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
