//! The flight recorder: a lock-light, per-thread ring buffer of structured
//! spans and events.
//!
//! Design:
//!
//! * **Disabled is free-ish.** Every instrumentation site starts with one
//!   relaxed atomic load; when the recorder is not installed, [`span`]
//!   returns an inert guard and nothing else happens. Hot paths (chunk
//!   loops, serve requests) stay instrumented unconditionally.
//! * **Per-thread rings.** Each recording thread owns an `Arc<ThreadRing>`
//!   holding its own mutex — uncontended in steady state, so recording is
//!   "lock-light": one never-shared lock acquisition per finished span.
//!   [`drain`] is the only cross-thread reader.
//! * **Oldest-first drop, never silent.** A full ring pops the oldest
//!   record and increments an explicit `dropped` counter that travels with
//!   every export — truncation is always visible in the trace footer.
//! * **Ids are global.** Span ids come from one process-wide counter
//!   (starting at 1; parent 0 means "root"), so cross-thread parent links
//!   (leader pass span → pool shard task) are just a `u64` handed into the
//!   task closure via [`span_child_of`].
//!
//! Timing: wall time from a process-wide [`Instant`] epoch; CPU time from
//! `CLOCK_THREAD_CPUTIME_ID` on Linux (0 elsewhere), so a span whose
//! `cpu_ns` ≪ `wall_ns` was blocked on I/O or a queue, not computing.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Default per-thread ring capacity (spans), used by `install_default`.
pub const DEFAULT_CAPACITY: usize = 8192;

/// A typed span/event attribute value.
#[derive(Debug, Clone, PartialEq)]
pub enum AttrValue {
    U64(u64),
    I64(i64),
    F64(f64),
    Str(String),
}

impl AttrValue {
    pub fn to_json(&self) -> Json {
        match self {
            AttrValue::U64(v) => Json::Num(*v as f64),
            AttrValue::I64(v) => Json::Num(*v as f64),
            AttrValue::F64(v) => Json::Num(*v),
            AttrValue::Str(s) => Json::Str(s.clone()),
        }
    }
}

impl From<u64> for AttrValue {
    fn from(v: u64) -> AttrValue {
        AttrValue::U64(v)
    }
}
impl From<usize> for AttrValue {
    fn from(v: usize) -> AttrValue {
        AttrValue::U64(v as u64)
    }
}
impl From<u32> for AttrValue {
    fn from(v: u32) -> AttrValue {
        AttrValue::U64(v as u64)
    }
}
impl From<i64> for AttrValue {
    fn from(v: i64) -> AttrValue {
        AttrValue::I64(v)
    }
}
impl From<f64> for AttrValue {
    fn from(v: f64) -> AttrValue {
        AttrValue::F64(v)
    }
}
impl From<&str> for AttrValue {
    fn from(v: &str) -> AttrValue {
        AttrValue::Str(v.to_string())
    }
}
impl From<String> for AttrValue {
    fn from(v: String) -> AttrValue {
        AttrValue::Str(v)
    }
}

/// Span vs instantaneous event (an event is a zero-duration record).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordKind {
    Span,
    Event,
}

impl RecordKind {
    fn as_str(self) -> &'static str {
        match self {
            RecordKind::Span => "span",
            RecordKind::Event => "event",
        }
    }
}

/// One finished span (or event) as stored in the ring.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub kind: RecordKind,
    pub id: u64,
    /// Parent span id; 0 = root.
    pub parent: u64,
    pub name: &'static str,
    /// Recorder-assigned id of the thread that recorded this span.
    pub thread: u64,
    /// Start offset from the recorder epoch, nanoseconds.
    pub start_ns: u64,
    pub wall_ns: u64,
    /// Thread CPU time consumed inside the span (0 where unsupported).
    pub cpu_ns: u64,
    pub attrs: Vec<(&'static str, AttrValue)>,
}

impl SpanRecord {
    /// One JSONL line, fields in fixed (non-alphabetical) order so traces
    /// stay grep-friendly: `"name"` before `"attrs"`.
    pub fn to_jsonl(&self) -> String {
        let mut attrs = Json::obj();
        for (k, v) in &self.attrs {
            attrs.set(k, v.to_json());
        }
        format!(
            "{{\"kind\":\"{}\",\"id\":{},\"parent\":{},\"name\":\"{}\",\"thread\":{},\
             \"start_ns\":{},\"wall_ns\":{},\"cpu_ns\":{},\"attrs\":{}}}",
            self.kind.as_str(),
            self.id,
            self.parent,
            self.name,
            self.thread,
            self.start_ns,
            self.wall_ns,
            self.cpu_ns,
            attrs.to_string_compact()
        )
    }
}

/// A thread's private ring. `push` is called only by the owning thread;
/// `drain` only by the exporter — the mutex is effectively uncontended.
#[derive(Debug)]
pub(crate) struct ThreadRing {
    thread: u64,
    capacity: usize,
    buf: Mutex<VecDeque<SpanRecord>>,
    dropped: AtomicU64,
}

impl ThreadRing {
    pub(crate) fn new(thread: u64, capacity: usize) -> ThreadRing {
        ThreadRing {
            thread,
            capacity: capacity.max(1),
            buf: Mutex::new(VecDeque::new()),
            dropped: AtomicU64::new(0),
        }
    }

    /// Append a record, evicting the oldest (and counting it as dropped)
    /// when the ring is at capacity.
    pub(crate) fn push(&self, rec: SpanRecord) {
        let mut buf = self.buf.lock().unwrap();
        if buf.len() >= self.capacity {
            buf.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        buf.push_back(rec);
    }

    pub(crate) fn drain(&self) -> (Vec<SpanRecord>, u64) {
        let mut buf = self.buf.lock().unwrap();
        let records = buf.drain(..).collect();
        (records, self.dropped.swap(0, Ordering::Relaxed))
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_THREAD_ID: AtomicU64 = AtomicU64::new(1);
static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_CAPACITY);
/// Bumped by `install`; thread-locals holding a ring from an older
/// generation re-register, so `install` fully isolates a fresh recording.
static GENERATION: AtomicU64 = AtomicU64::new(0);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn rings() -> &'static Mutex<Vec<Arc<ThreadRing>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<ThreadRing>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

struct Local {
    generation: u64,
    ring: Option<Arc<ThreadRing>>,
    stack: Vec<u64>,
}

thread_local! {
    static LOCAL: RefCell<Local> = const {
        RefCell::new(Local { generation: 0, ring: None, stack: Vec::new() })
    };
}

fn with_ring<R>(f: impl FnOnce(&ThreadRing) -> R) -> R {
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let generation = GENERATION.load(Ordering::Relaxed);
        if l.ring.is_none() || l.generation != generation {
            let ring = Arc::new(ThreadRing::new(
                NEXT_THREAD_ID.fetch_add(1, Ordering::Relaxed),
                CAPACITY.load(Ordering::Relaxed),
            ));
            rings().lock().unwrap().push(Arc::clone(&ring));
            l.ring = Some(ring);
            l.generation = generation;
        }
        f(l.ring.as_ref().expect("ring just installed"))
    })
}

#[cfg(target_os = "linux")]
fn thread_cpu_ns() -> u64 {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    extern "C" {
        fn clock_gettime(clk_id: i32, tp: *mut Timespec) -> i32;
    }
    const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    let mut ts = Timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: clock_gettime writes a Timespec through a valid pointer; std
    // already links the C runtime that provides it.
    let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    if rc == 0 {
        (ts.tv_sec as u64).saturating_mul(1_000_000_000) + ts.tv_nsec as u64
    } else {
        0
    }
}

#[cfg(not(target_os = "linux"))]
fn thread_cpu_ns() -> u64 {
    0
}

/// Enable the recorder with the given per-thread ring capacity, resetting
/// any previously recorded (undrained) data.
pub fn install(capacity: usize) {
    let _ = epoch();
    CAPACITY.store(capacity.max(1), Ordering::Relaxed);
    rings().lock().unwrap().clear();
    GENERATION.fetch_add(1, Ordering::Relaxed);
    ENABLED.store(true, Ordering::Relaxed);
}

/// `install(DEFAULT_CAPACITY)`.
pub fn install_default() {
    install(DEFAULT_CAPACITY);
}

/// [`install`], then start span ids at `base` instead of continuing the
/// process counter. A cluster driver hands each worker a disjoint id
/// namespace (e.g. `(worker_index + 1) << 40`) so spans merged across
/// processes never collide and cross-process parent links stay exact.
pub fn install_with_base(capacity: usize, base: u64) {
    install(capacity);
    NEXT_SPAN_ID.store(base.max(1), Ordering::Relaxed);
}

/// Nanoseconds since the recorder epoch on this process's monotonic clock —
/// the timestamp basis of every recorded span. Exposed so the cluster
/// layer can stamp RunPass frames (driver) and estimate clock skew against
/// them (worker).
pub fn now_ns() -> u64 {
    Instant::now().duration_since(epoch()).as_nanos() as u64
}

/// Stop recording. Already-buffered spans stay drainable.
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// A drained recording: every buffered span across all threads plus the
/// total number of records the rings had to evict.
#[derive(Debug)]
pub struct Trace {
    pub spans: Vec<SpanRecord>,
    pub dropped: u64,
}

impl Trace {
    /// Write JSONL: one span per line (start-time order) and a final
    /// `{"kind":"trace",...}` footer carrying the drop counter, so
    /// truncation is never silent.
    pub fn write_jsonl(&self, path: &Path) -> std::io::Result<()> {
        let mut out = String::new();
        for s in &self.spans {
            out.push_str(&s.to_jsonl());
            out.push('\n');
        }
        out.push_str(&format!(
            "{{\"kind\":\"trace\",\"spans\":{},\"dropped\":{}}}\n",
            self.spans.len(),
            self.dropped
        ));
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(out.as_bytes())
    }
}

/// Collect and clear every thread's buffered spans (sorted by start time).
pub fn drain() -> Trace {
    let rings = rings().lock().unwrap();
    let mut spans = Vec::new();
    let mut dropped = 0u64;
    for ring in rings.iter() {
        let (mut records, d) = ring.drain();
        spans.append(&mut records);
        dropped += d;
    }
    spans.sort_by_key(|s| (s.start_ns, s.id));
    Trace { spans, dropped }
}

/// Drain and write JSONL in one step; returns `(spans, dropped)`.
pub fn export_jsonl(path: &Path) -> std::io::Result<(usize, u64)> {
    let trace = drain();
    trace.write_jsonl(path)?;
    Ok((trace.spans.len(), trace.dropped))
}

/// An in-flight span. Records itself (wall + CPU + attrs) into the current
/// thread's ring when dropped; inert (id 0) while the recorder is disabled.
#[derive(Debug)]
pub struct Span {
    id: u64,
    parent: u64,
    name: &'static str,
    start: Option<Instant>,
    start_ns: u64,
    cpu_start: u64,
    attrs: Vec<(&'static str, AttrValue)>,
}

impl Span {
    fn inert(name: &'static str) -> Span {
        Span {
            id: 0,
            parent: 0,
            name,
            start: None,
            start_ns: 0,
            cpu_start: 0,
            attrs: Vec::new(),
        }
    }

    fn armed(name: &'static str, parent: u64) -> Span {
        let now = Instant::now();
        let span = Span {
            id: NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed),
            parent,
            name,
            start: Some(now),
            start_ns: now.duration_since(epoch()).as_nanos() as u64,
            cpu_start: thread_cpu_ns(),
            attrs: Vec::new(),
        };
        LOCAL.with(|l| l.borrow_mut().stack.push(span.id));
        span
    }

    /// This span's id, for parenting work handed to other threads.
    /// 0 when the recorder is disabled.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Attach a typed attribute (no-op while disabled).
    pub fn attr(&mut self, key: &'static str, value: impl Into<AttrValue>) -> &mut Self {
        if self.start.is_some() {
            self.attrs.push((key, value.into()));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let wall_ns = start.elapsed().as_nanos() as u64;
        let cpu_ns = thread_cpu_ns().saturating_sub(self.cpu_start);
        LOCAL.with(|l| {
            let mut l = l.borrow_mut();
            // Pop our own id (spans nest strictly on a thread, so it is the
            // top unless an earlier generation reset raced us).
            if l.stack.last() == Some(&self.id) {
                l.stack.pop();
            } else if let Some(pos) = l.stack.iter().rposition(|&id| id == self.id) {
                l.stack.truncate(pos);
            }
        });
        let mut rec = SpanRecord {
            kind: RecordKind::Span,
            id: self.id,
            parent: self.parent,
            name: self.name,
            thread: 0, // assigned below from the ring
            start_ns: self.start_ns,
            wall_ns,
            cpu_ns,
            attrs: std::mem::take(&mut self.attrs),
        };
        with_ring(move |ring| {
            rec.thread = ring.thread;
            ring.push(rec);
        });
    }
}

/// Open a span whose parent is the innermost open span on this thread
/// (root if none).
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span::inert(name);
    }
    let parent = LOCAL.with(|l| l.borrow().stack.last().copied().unwrap_or(0));
    Span::armed(name, parent)
}

/// Open a span under an explicit parent id — the cross-thread variant
/// (e.g. a pool shard task parented to the leader's pass span). Nested
/// same-thread spans chain under it as usual.
pub fn span_child_of(name: &'static str, parent: u64) -> Span {
    if !enabled() {
        return Span::inert(name);
    }
    Span::armed(name, parent)
}

/// Record an instantaneous event under the innermost open span.
pub fn event(name: &'static str, attrs: Vec<(&'static str, AttrValue)>) {
    if !enabled() {
        return;
    }
    let parent = LOCAL.with(|l| l.borrow().stack.last().copied().unwrap_or(0));
    let now = Instant::now();
    with_ring(|ring| {
        ring.push(SpanRecord {
            kind: RecordKind::Event,
            id: NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed),
            parent,
            name,
            thread: ring.thread,
            start_ns: now.duration_since(epoch()).as_nanos() as u64,
            wall_ns: 0,
            cpu_ns: 0,
            attrs,
        });
    });
}

/// Record an already-measured span (e.g. the leader's accumulated reduce
/// time, which interleaves with the receive loop and has no contiguous
/// guard scope). `start_ns` is back-dated so the span sits inside its
/// parent on the timeline.
pub fn record_manual(
    name: &'static str,
    parent: u64,
    wall_ns: u64,
    attrs: Vec<(&'static str, AttrValue)>,
) {
    if !enabled() {
        return;
    }
    let end_ns = Instant::now().duration_since(epoch()).as_nanos() as u64;
    with_ring(|ring| {
        ring.push(SpanRecord {
            kind: RecordKind::Span,
            id: NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed),
            parent,
            name,
            thread: ring.thread,
            start_ns: end_ns.saturating_sub(wall_ns),
            wall_ns,
            cpu_ns: 0,
            attrs,
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_oldest_first_and_counts() {
        let ring = ThreadRing::new(9, 4);
        for i in 0..10u64 {
            ring.push(SpanRecord {
                kind: RecordKind::Span,
                id: i + 1,
                parent: 0,
                name: "s",
                thread: 9,
                start_ns: i,
                wall_ns: 1,
                cpu_ns: 0,
                attrs: vec![],
            });
        }
        let (records, dropped) = ring.drain();
        assert_eq!(dropped, 6, "10 pushed into capacity 4");
        let ids: Vec<u64> = records.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![7, 8, 9, 10], "survivors are the newest, in order");
        // Draining resets both the buffer and the counter.
        let (records, dropped) = ring.drain();
        assert!(records.is_empty());
        assert_eq!(dropped, 0);
    }

    #[test]
    fn jsonl_line_is_valid_json_with_ordered_fields() {
        let rec = SpanRecord {
            kind: RecordKind::Span,
            id: 5,
            parent: 2,
            name: "pass",
            thread: 1,
            start_ns: 100,
            wall_ns: 250,
            cpu_ns: 240,
            attrs: vec![("kind", AttrValue::from("power")), ("shards", 3usize.into())],
        };
        let line = rec.to_jsonl();
        let name_at = line.find("\"name\":\"pass\"").unwrap();
        let attrs_at = line.find("\"attrs\"").unwrap();
        assert!(name_at < attrs_at, "name precedes attrs for greppability");
        let doc = crate::util::json::parse(&line).unwrap();
        assert_eq!(doc.get("id").unwrap().as_usize(), Some(5));
        assert_eq!(doc.get("parent").unwrap().as_usize(), Some(2));
        assert_eq!(
            doc.get("attrs").unwrap().get("kind").unwrap().as_str(),
            Some("power")
        );
    }

    #[test]
    fn disabled_spans_are_inert() {
        // Do not install: whatever other tests do, an inert span must keep
        // id 0 and record nothing through this guard.
        let before = enabled();
        if before {
            // Another test currently owns the global recorder; skip.
            return;
        }
        let mut s = span("never");
        s.attr("k", 1u64);
        if enabled() {
            // A parallel test installed the recorder mid-flight; the span
            // may legitimately be live now. Nothing to assert.
            return;
        }
        assert_eq!(s.id(), 0);
    }
}
