//! One metrics pipeline: a registry of named sources, each exporting its
//! pre-existing JSON snapshot (bitwise-compatible with what the source
//! served before unification) plus Prometheus text-format families.
//!
//! `coordinator::Metrics`, `serve::ServeMetrics`, and the serve per-endpoint
//! SLO table all implement [`MetricSource`]; a server registers them once
//! and `GET /metrics?format=prom` renders everything in registration order.
//! The JSON shape is produced by each source's own `snapshot()` untouched,
//! so existing scrapers and golden tests keep working byte for byte.
//!
//! Histogram exports carry the exact `sum`/`count` (and the derived mean as
//! a companion `_mean` gauge) alongside the power-of-two buckets: bucketed
//! quantiles overestimate by up to 2× (see `serve::metrics::Histogram`), so
//! the mean is the only *exact* central tendency in the exposition and must
//! never be dropped in favor of the quantiles.

use crate::util::json::Json;
use std::sync::{Arc, Mutex};

/// A provider of metrics: its legacy JSON snapshot and its prom families.
pub trait MetricSource: Send + Sync {
    /// The source's pre-unification JSON shape, unchanged.
    fn snapshot_json(&self) -> Json;
    /// Prometheus families, fully named (e.g. `rcca_serve_requests_total`).
    fn prom_families(&self) -> Vec<Family>;
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FamilyKind {
    Counter,
    Gauge,
    Histogram,
}

impl FamilyKind {
    fn as_str(self) -> &'static str {
        match self {
            FamilyKind::Counter => "counter",
            FamilyKind::Gauge => "gauge",
            FamilyKind::Histogram => "histogram",
        }
    }
}

/// One sample within a family: optional name suffix (`_bucket`, `_sum`,
/// `_count` for histograms), label pairs, value.
#[derive(Debug, Clone)]
pub struct Sample {
    pub suffix: &'static str,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// One metric family: `# HELP` / `# TYPE` header plus its samples.
#[derive(Debug, Clone)]
pub struct Family {
    pub name: String,
    pub help: String,
    pub kind: FamilyKind,
    pub samples: Vec<Sample>,
}

/// Counter family with a single unlabeled sample.
pub fn counter(name: &str, help: &str, value: u64) -> Family {
    Family {
        name: name.to_string(),
        help: help.to_string(),
        kind: FamilyKind::Counter,
        samples: vec![Sample {
            suffix: "",
            labels: vec![],
            value: value as f64,
        }],
    }
}

/// Gauge family with a single unlabeled sample.
pub fn gauge(name: &str, help: &str, value: f64) -> Family {
    Family {
        name: name.to_string(),
        help: help.to_string(),
        kind: FamilyKind::Gauge,
        samples: vec![Sample {
            suffix: "",
            labels: vec![],
            value,
        }],
    }
}

/// Gauge family with one sample per `(label value, sample value)` pair.
pub fn gauge_vec(name: &str, help: &str, label: &str, values: &[(String, f64)]) -> Family {
    Family {
        name: name.to_string(),
        help: help.to_string(),
        kind: FamilyKind::Gauge,
        samples: values
            .iter()
            .map(|(lv, v)| Sample {
                suffix: "",
                labels: vec![(label.to_string(), lv.clone())],
                value: *v,
            })
            .collect(),
    }
}

/// Counter family with one sample per `(label value, count)` pair — e.g.
/// the cluster audit trail's per-kind event tallies.
pub fn counter_vec(name: &str, help: &str, label: &str, values: &[(String, u64)]) -> Family {
    Family {
        name: name.to_string(),
        help: help.to_string(),
        kind: FamilyKind::Counter,
        samples: values
            .iter()
            .map(|(lv, v)| Sample {
                suffix: "",
                labels: vec![(label.to_string(), lv.clone())],
                value: *v as f64,
            })
            .collect(),
    }
}

/// A histogram flattened for export: cumulative `(le, count)` pairs ending
/// with the `+Inf` bucket, plus exact sum/count and the derived mean.
#[derive(Debug, Clone)]
pub struct HistogramSnapshot {
    /// Cumulative counts; `le = f64::INFINITY` for the overflow bucket.
    pub buckets: Vec<(f64, u64)>,
    pub sum: f64,
    pub count: u64,
}

impl HistogramSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// Histogram family with per-sample base labels (one snapshot per label
/// set — e.g. per-endpoint latency). Emits `_bucket`/`_sum`/`_count`.
pub fn histogram_vec(
    name: &str,
    help: &str,
    snaps: &[(Vec<(String, String)>, HistogramSnapshot)],
) -> Family {
    let mut samples = Vec::new();
    for (labels, snap) in snaps {
        for &(le, cumulative) in &snap.buckets {
            let mut l = labels.clone();
            l.push(("le".to_string(), fmt_le(le)));
            samples.push(Sample {
                suffix: "_bucket",
                labels: l,
                value: cumulative as f64,
            });
        }
        samples.push(Sample {
            suffix: "_sum",
            labels: labels.clone(),
            value: snap.sum,
        });
        samples.push(Sample {
            suffix: "_count",
            labels: labels.clone(),
            value: snap.count as f64,
        });
    }
    Family {
        name: name.to_string(),
        help: help.to_string(),
        kind: FamilyKind::Histogram,
        samples,
    }
}

/// Unlabeled single-histogram convenience over [`histogram_vec`].
pub fn histogram(name: &str, help: &str, snap: &HistogramSnapshot) -> Family {
    histogram_vec(name, help, std::slice::from_ref(&(vec![], snap.clone())))
}

fn fmt_le(le: f64) -> String {
    if le.is_infinite() {
        "+Inf".to_string()
    } else {
        fmt_value(le)
    }
}

/// Prometheus sample-value formatting: integral values without a fraction.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Render families as Prometheus text exposition format (version 0.0.4).
pub fn render_families(families: &[Family], out: &mut String) {
    for f in families {
        out.push_str(&format!("# HELP {} {}\n", f.name, f.help));
        out.push_str(&format!("# TYPE {} {}\n", f.name, f.kind.as_str()));
        for s in &f.samples {
            out.push_str(&f.name);
            out.push_str(s.suffix);
            if !s.labels.is_empty() {
                out.push('{');
                for (i, (k, v)) in s.labels.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&format!("{k}=\"{}\"", escape_label(v)));
                }
                out.push('}');
            }
            out.push(' ');
            out.push_str(&fmt_value(s.value));
            out.push('\n');
        }
    }
}

/// The unified registry: named sources rendered together, in registration
/// order. Registering a name twice replaces the earlier source (hot-swap).
#[derive(Default)]
pub struct MetricsRegistry {
    sources: Mutex<Vec<(String, Arc<dyn MetricSource>)>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn register(&self, name: &str, source: Arc<dyn MetricSource>) {
        let mut sources = self.sources.lock().unwrap();
        if let Some(slot) = sources.iter_mut().find(|(n, _)| n == name) {
            slot.1 = source;
        } else {
            sources.push((name.to_string(), source));
        }
    }

    /// `{source_name: legacy_snapshot, ...}` — each snapshot unchanged.
    pub fn render_json(&self) -> Json {
        let sources = self.sources.lock().unwrap();
        let mut o = Json::obj();
        for (name, src) in sources.iter() {
            o.set(name, src.snapshot_json());
        }
        o
    }

    /// Full Prometheus text exposition across every registered source.
    pub fn render_prom(&self) -> String {
        let sources = self.sources.lock().unwrap();
        let mut out = String::new();
        for (_, src) in sources.iter() {
            render_families(&src.prom_families(), &mut out);
        }
        out
    }
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let names: Vec<String> = self
            .sources
            .lock()
            .unwrap()
            .iter()
            .map(|(n, _)| n.clone())
            .collect();
        f.debug_struct("MetricsRegistry")
            .field("sources", &names)
            .finish()
    }
}

/// Parse a prom text exposition back into `(name_with_labels, value)`
/// pairs — a deliberately small reader used by round-trip tests and the
/// trace CLI, not a full scraper.
pub fn parse_prom(text: &str) -> Result<Vec<(String, f64)>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no value separator: {line}", i + 1))?;
        let v: f64 = value
            .parse()
            .map_err(|_| format!("line {}: bad value {value:?}", i + 1))?;
        out.push((name.to_string(), v));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::jnum;

    struct Fixed;
    impl MetricSource for Fixed {
        fn snapshot_json(&self) -> Json {
            let mut o = Json::obj();
            o.set("hits", jnum(7.0));
            o
        }
        fn prom_families(&self) -> Vec<Family> {
            vec![counter("rcca_test_hits", "hits", 7)]
        }
    }

    #[test]
    fn registry_renders_both_shapes_and_replaces_by_name() {
        let reg = MetricsRegistry::new();
        reg.register("test", Arc::new(Fixed));
        reg.register("test", Arc::new(Fixed)); // replace, not duplicate
        let json = reg.render_json();
        assert_eq!(
            json.get("test").unwrap().get("hits").unwrap().as_usize(),
            Some(7)
        );
        let prom = reg.render_prom();
        assert_eq!(prom.matches("rcca_test_hits 7").count(), 1, "{prom}");
        assert!(prom.contains("# TYPE rcca_test_hits counter"));
    }

    #[test]
    fn histogram_family_emits_cumulative_buckets_and_exact_sum() {
        let snap = HistogramSnapshot {
            buckets: vec![(1.0, 2), (4.0, 5), (f64::INFINITY, 6)],
            sum: 23.0,
            count: 6,
        };
        let fam = histogram("rcca_test_lat", "lat", &snap);
        let mut text = String::new();
        render_families(&[fam], &mut text);
        assert!(text.contains("rcca_test_lat_bucket{le=\"1\"} 2"), "{text}");
        assert!(text.contains("rcca_test_lat_bucket{le=\"4\"} 5"), "{text}");
        assert!(text.contains("rcca_test_lat_bucket{le=\"+Inf\"} 6"), "{text}");
        assert!(text.contains("rcca_test_lat_sum 23"), "{text}");
        assert!(text.contains("rcca_test_lat_count 6"), "{text}");
        assert!((snap.mean() - 23.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn parse_prom_roundtrips_rendered_values() {
        let fams = vec![
            counter("rcca_a_total", "a", 41),
            gauge_vec(
                "rcca_dir",
                "per direction",
                "direction",
                &[("0".to_string(), 0.5), ("1".to_string(), -0.25)],
            ),
        ];
        let mut text = String::new();
        render_families(&fams, &mut text);
        let parsed = parse_prom(&text).unwrap();
        assert!(parsed.contains(&("rcca_a_total".to_string(), 41.0)));
        assert!(parsed.contains(&("rcca_dir{direction=\"0\"}".to_string(), 0.5)));
        assert!(parsed.contains(&("rcca_dir{direction=\"1\"}".to_string(), -0.25)));
    }
}
