//! # `rcca::chaos` — crate-wide deterministic fault injection.
//!
//! One place for every fault plan in the system. Chaos here is never
//! random at run time: each fault fires at an exact, pre-declared point
//! (a pass index, a request ordinal, a fixed delay), so a chaos run is as
//! reproducible as a clean one — which is what lets tests and CI assert
//! *bitwise* equality between work that survived injected failures and an
//! uninterrupted reference, and *exact* status-code semantics on the
//! serving side.
//!
//! Two plan families share the same `key[=value],key,...` spec grammar:
//!
//! * [`ClusterPlan`] (`repro worker --chaos`, `repro fit --chaos`) —
//!   fit-side faults: worker kills, dropped heartbeats, straggler delays,
//!   driver halts, torn checkpoints. Grown in the cluster subsystem
//!   (PR 8) and hoisted here unchanged; `crate::cluster::ChaosPlan`
//!   remains as an alias for existing call sites.
//! * [`ServePlan`] (`repro serve --chaos`) — serve-side faults: stalled
//!   request reads, torn response writes, batcher stalls and injected
//!   batcher failures, corrupt-model reloads, and handler panics. Each
//!   fault carries a *finite budget* (a count), so a chaos'd server is
//!   guaranteed to recover once the budgets drain — the property the
//!   overload soak test and the CI serve-chaos smoke assert.
//!
//! Unknown keys and malformed values are typed errors, not silent no-ops:
//! a chaos drill that never fires is worse than one that fails loudly.

pub mod cluster;
pub mod serve;

pub use cluster::ClusterPlan;
pub use serve::{ServeChaos, ServePlan};
