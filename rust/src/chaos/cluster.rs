//! Deterministic fault injection for the cluster: a seeded, declarative
//! plan of *when* to misbehave, shared by tests, CI drills, and the
//! `--chaos` flags on `repro worker` and `repro fit`.
//!
//! Every fault fires at an exact, pre-declared point (a pass index, a
//! fixed delay), so a chaos run is as reproducible as a clean one — which
//! is what lets CI assert *bitwise* equality between a fit that survived
//! injected failures and an uninterrupted reference fit. The `seed` key
//! exists so future probabilistic extensions stay deterministic; today it
//! only labels the plan.
//!
//! Spec grammar (comma-separated `key[=value]` pairs):
//!
//! ```text
//! kill-at-pass=N      worker: exit(9) after sending its first partial of
//!                     pass N (no goodbye — the driver sees a dead peer)
//! drop-heartbeats=N   worker: stop echoing heartbeats from pass N onward
//!                     (the hung-process failure mode, driving the
//!                     driver's heartbeat-timeout burial)
//! delay-partial=MS    worker: sleep MS milliseconds before each partial
//!                     (a straggler; must never change results)
//! die-after-pass=N    driver: halt with an error right after pass N is
//!                     reduced (and checkpointed, when a checkpoint path
//!                     is configured) — the crash `--resume` recovers from
//! torn-checkpoint     driver: truncate the checkpoint file after every
//!                     write, exercising the fail-closed torn-file path
//! seed=N              label for the plan (reserved for future use)
//! ```
//!
//! Unknown keys and malformed values are typed errors, not silent no-ops:
//! a chaos drill that never fires is worse than one that fails loudly.

/// A parsed, validated cluster chaos plan. `Default` injects nothing.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ClusterPlan {
    /// Worker: crash (exit 9) after sending the first partial of this pass.
    pub kill_at_pass: Option<u64>,
    /// Worker: stop echoing heartbeats from this pass onward.
    pub drop_heartbeats_from: Option<u64>,
    /// Worker: sleep this long before sending each partial.
    pub delay_partial_ms: u64,
    /// Driver: halt with an error after reducing (and checkpointing) this
    /// pass.
    pub die_after_pass: Option<u64>,
    /// Driver: truncate the checkpoint after each write (torn-file drill).
    pub torn_checkpoint: bool,
    /// Plan label; reserved so future probabilistic faults stay seeded.
    pub seed: u64,
}

impl ClusterPlan {
    /// No faults at all — the plan every config defaults to.
    pub fn none() -> ClusterPlan {
        ClusterPlan::default()
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        *self == ClusterPlan::default()
    }

    /// Parse a `key=value,key,...` spec. The empty string is the empty
    /// plan, so CLI flags can default to `""`.
    pub fn parse(spec: &str) -> Result<ClusterPlan, String> {
        let mut plan = ClusterPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = match part.split_once('=') {
                Some((k, v)) => (k, Some(v)),
                None => (part, None),
            };
            let num = |field: &str| -> Result<u64, String> {
                val.ok_or_else(|| format!("chaos key '{field}' needs =<number>"))?
                    .parse::<u64>()
                    .map_err(|_| {
                        format!("chaos key '{field}' has a bad value '{}'", val.unwrap_or(""))
                    })
            };
            match key {
                "kill-at-pass" => plan.kill_at_pass = Some(num(key)?),
                "drop-heartbeats" => plan.drop_heartbeats_from = Some(num(key)?),
                "delay-partial" => plan.delay_partial_ms = num(key)?,
                "die-after-pass" => plan.die_after_pass = Some(num(key)?),
                "torn-checkpoint" => {
                    if val.is_some() {
                        return Err("chaos key 'torn-checkpoint' takes no value".to_string());
                    }
                    plan.torn_checkpoint = true;
                }
                "seed" => plan.seed = num(key)?,
                other => {
                    return Err(format!(
                        "unknown chaos key '{other}' (expected kill-at-pass|drop-heartbeats|\
                         delay-partial|die-after-pass|torn-checkpoint|seed)"
                    ))
                }
            }
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_is_the_empty_plan() {
        let plan = ClusterPlan::parse("").unwrap();
        assert!(plan.is_empty());
        assert_eq!(plan, ClusterPlan::none());
    }

    #[test]
    fn full_spec_parses() {
        let plan = ClusterPlan::parse(
            "kill-at-pass=1,drop-heartbeats=2,delay-partial=15,die-after-pass=1,\
             torn-checkpoint,seed=42",
        )
        .unwrap();
        assert_eq!(plan.kill_at_pass, Some(1));
        assert_eq!(plan.drop_heartbeats_from, Some(2));
        assert_eq!(plan.delay_partial_ms, 15);
        assert_eq!(plan.die_after_pass, Some(1));
        assert!(plan.torn_checkpoint);
        assert_eq!(plan.seed, 42);
        assert!(!plan.is_empty());
    }

    #[test]
    fn whitespace_and_empty_parts_are_tolerated() {
        let plan = ClusterPlan::parse(" kill-at-pass=3 , ,seed=7 ").unwrap();
        assert_eq!(plan.kill_at_pass, Some(3));
        assert_eq!(plan.seed, 7);
    }

    #[test]
    fn unknown_key_is_a_typed_error() {
        let err = ClusterPlan::parse("explode-now=1").unwrap_err();
        assert!(err.contains("unknown chaos key 'explode-now'"), "{err}");
    }

    #[test]
    fn bad_values_are_typed_errors() {
        assert!(ClusterPlan::parse("kill-at-pass").unwrap_err().contains("needs"));
        assert!(ClusterPlan::parse("kill-at-pass=x").unwrap_err().contains("bad value"));
        assert!(ClusterPlan::parse("torn-checkpoint=1").unwrap_err().contains("no value"));
    }

    #[test]
    fn cluster_alias_still_resolves() {
        // `crate::cluster::ChaosPlan` is the historical name; the alias
        // must keep existing call sites (engine specs, CLI) compiling.
        let plan = crate::cluster::ChaosPlan::parse("delay-partial=5").unwrap();
        assert_eq!(plan.delay_partial_ms, 5);
    }
}
