//! Deterministic fault injection for the serving path (`repro serve
//! --chaos`): every fault has a *finite budget*, consumed atomically in
//! arrival order, so a chaos'd server provably recovers once the budgets
//! drain — the property the overload soak test and the CI serve-chaos
//! smoke pin (breaker closes again, `/healthz` returns to `ok`).
//!
//! Spec grammar (comma-separated `key=value` pairs). Delay faults take
//! `COUNTxMS` (fire COUNT times, MS milliseconds each); count faults take
//! a plain `COUNT`:
//!
//! ```text
//! stall-read=NxMS     stall MS after reading each of the first N
//!                     requests (a stuck parse/read path — burns the
//!                     request's deadline budget before dispatch)
//! torn-write=N        tear the first N responses: write half the status
//!                     line, then hard-close the socket
//! batcher-stall=NxMS  the batch worker sleeps MS before running each of
//!                     the first N batches (drives deadline expiry at the
//!                     batcher wait)
//! batcher-fail=N      the batch worker answers the first N batches with
//!                     an injected internal error (drives the circuit
//!                     breaker open, then half-open recovery)
//! corrupt-reload=N    the next N /admin/reload attempts fail as if the
//!                     on-disk document were corrupt (healthz degrades;
//!                     the pinned generation keeps serving)
//! worker-panic=N      panic mid-handler on the first N transform
//!                     requests (the pool contains it; the client sees a
//!                     closed connection, never a hung one)
//! seed=N              label for the plan (reserved for future use)
//! ```
//!
//! Unknown keys and malformed values are typed errors, not silent no-ops.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// A parsed, validated serve chaos plan. `Default` injects nothing.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ServePlan {
    /// Stall (count, millis) after reading each of the first `count`
    /// requests, before dispatch.
    pub stall_read: Option<(u64, u64)>,
    /// Tear the first N responses (half a status line, then close).
    pub torn_write: u64,
    /// Batch worker sleeps (count, millis) before the first `count` batches.
    pub batcher_stall: Option<(u64, u64)>,
    /// Batch worker fails the first N batches with an injected error.
    pub batcher_fail: u64,
    /// Fail the next N reload attempts as if the document were corrupt.
    pub corrupt_reload: u64,
    /// Panic mid-handler on the first N transform requests.
    pub worker_panic: u64,
    /// Plan label; reserved so future probabilistic faults stay seeded.
    pub seed: u64,
}

impl ServePlan {
    /// No faults at all — the plan every config defaults to.
    pub fn none() -> ServePlan {
        ServePlan::default()
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        *self == ServePlan::default()
    }

    /// Parse a `key=value,...` spec. The empty string is the empty plan,
    /// so CLI flags can default to `""`.
    pub fn parse(spec: &str) -> Result<ServePlan, String> {
        let mut plan = ServePlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = match part.split_once('=') {
                Some((k, v)) => (k, Some(v)),
                None => (part, None),
            };
            let raw = |field: &str| -> Result<&str, String> {
                val.ok_or_else(|| format!("chaos key '{field}' needs =<value>"))
            };
            let count = |field: &str| -> Result<u64, String> {
                raw(field)?.parse::<u64>().map_err(|_| {
                    format!(
                        "chaos key '{field}' has a bad value '{}' (expected a count)",
                        val.unwrap_or("")
                    )
                })
            };
            // Delay faults: COUNTxMS, both parts required.
            let count_ms = |field: &str| -> Result<(u64, u64), String> {
                let v = raw(field)?;
                let (n, ms) = v.split_once('x').ok_or_else(|| {
                    format!("chaos key '{field}' takes COUNTxMS (e.g. {field}=2x400), got '{v}'")
                })?;
                let parse = |s: &str| {
                    s.parse::<u64>()
                        .map_err(|_| format!("chaos key '{field}' has a bad value '{v}'"))
                };
                Ok((parse(n)?, parse(ms)?))
            };
            match key {
                "stall-read" => plan.stall_read = Some(count_ms(key)?),
                "torn-write" => plan.torn_write = count(key)?,
                "batcher-stall" => plan.batcher_stall = Some(count_ms(key)?),
                "batcher-fail" => plan.batcher_fail = count(key)?,
                "corrupt-reload" => plan.corrupt_reload = count(key)?,
                "worker-panic" => plan.worker_panic = count(key)?,
                "seed" => plan.seed = count(key)?,
                other => {
                    return Err(format!(
                        "unknown serve chaos key '{other}' (expected stall-read|torn-write|\
                         batcher-stall|batcher-fail|corrupt-reload|worker-panic|seed)"
                    ))
                }
            }
        }
        Ok(plan)
    }
}

/// Runtime state for a [`ServePlan`]: per-fault budgets consumed atomically
/// in arrival order. Cheap to probe on the hot path — an empty plan is one
/// relaxed load per injection point.
#[derive(Debug)]
pub struct ServeChaos {
    plan: ServePlan,
    stall_read_left: AtomicU64,
    torn_write_left: AtomicU64,
    batcher_stall_left: AtomicU64,
    batcher_fail_left: AtomicU64,
    corrupt_reload_left: AtomicU64,
    worker_panic_left: AtomicU64,
    injected: AtomicU64,
}

impl ServeChaos {
    pub fn new(plan: ServePlan) -> ServeChaos {
        ServeChaos {
            stall_read_left: AtomicU64::new(plan.stall_read.map_or(0, |(n, _)| n)),
            torn_write_left: AtomicU64::new(plan.torn_write),
            batcher_stall_left: AtomicU64::new(plan.batcher_stall.map_or(0, |(n, _)| n)),
            batcher_fail_left: AtomicU64::new(plan.batcher_fail),
            corrupt_reload_left: AtomicU64::new(plan.corrupt_reload),
            worker_panic_left: AtomicU64::new(plan.worker_panic),
            injected: AtomicU64::new(0),
            plan,
        }
    }

    pub fn plan(&self) -> &ServePlan {
        &self.plan
    }

    /// Total faults injected so far (observability; exported on the prom
    /// metrics surface as `rcca_serve_chaos_injections_total`).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Consume one unit of `budget` if any remains. Lock-free; over-decrement
    /// races are resolved by compare-exchange so exactly `n` faults fire.
    fn take(&self, budget: &AtomicU64) -> bool {
        let mut left = budget.load(Ordering::Relaxed);
        while left > 0 {
            match budget.compare_exchange_weak(left, left - 1, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
                Err(now) => left = now,
            }
        }
        false
    }

    /// Stall to apply after reading a request, if the budget allows.
    pub fn stall_read(&self) -> Option<Duration> {
        let (_, ms) = self.plan.stall_read?;
        self.take(&self.stall_read_left)
            .then(|| Duration::from_millis(ms))
    }

    /// True when this response should be torn mid-write.
    pub fn torn_write(&self) -> bool {
        self.take(&self.torn_write_left)
    }

    /// Stall to apply before running a batch, if the budget allows.
    pub fn batcher_stall(&self) -> Option<Duration> {
        let (_, ms) = self.plan.batcher_stall?;
        self.take(&self.batcher_stall_left)
            .then(|| Duration::from_millis(ms))
    }

    /// True when this batch should fail with an injected error.
    pub fn batcher_fail(&self) -> bool {
        self.take(&self.batcher_fail_left)
    }

    /// True when this reload attempt should fail as if corrupt.
    pub fn corrupt_reload(&self) -> bool {
        self.take(&self.corrupt_reload_left)
    }

    /// True when this transform handler should panic.
    pub fn worker_panic(&self) -> bool {
        self.take(&self.worker_panic_left)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_is_the_empty_plan() {
        let plan = ServePlan::parse("").unwrap();
        assert!(plan.is_empty());
        assert_eq!(plan, ServePlan::none());
    }

    #[test]
    fn full_spec_parses() {
        let plan = ServePlan::parse(
            "stall-read=2x500,torn-write=1,batcher-stall=3x250,batcher-fail=3,\
             corrupt-reload=1,worker-panic=2,seed=9",
        )
        .unwrap();
        assert_eq!(plan.stall_read, Some((2, 500)));
        assert_eq!(plan.torn_write, 1);
        assert_eq!(plan.batcher_stall, Some((3, 250)));
        assert_eq!(plan.batcher_fail, 3);
        assert_eq!(plan.corrupt_reload, 1);
        assert_eq!(plan.worker_panic, 2);
        assert_eq!(plan.seed, 9);
        assert!(!plan.is_empty());
    }

    #[test]
    fn delay_faults_require_countxms() {
        let err = ServePlan::parse("batcher-stall=400").unwrap_err();
        assert!(err.contains("COUNTxMS"), "{err}");
        let err = ServePlan::parse("stall-read=ax4").unwrap_err();
        assert!(err.contains("bad value"), "{err}");
    }

    #[test]
    fn unknown_key_is_a_typed_error() {
        let err = ServePlan::parse("explode=1").unwrap_err();
        assert!(err.contains("unknown serve chaos key 'explode'"), "{err}");
        assert!(ServePlan::parse("torn-write").unwrap_err().contains("needs"));
        assert!(ServePlan::parse("torn-write=x").unwrap_err().contains("bad value"));
    }

    #[test]
    fn budgets_drain_exactly() {
        let chaos = ServeChaos::new(ServePlan::parse("batcher-fail=2,batcher-stall=1x50").unwrap());
        assert!(chaos.batcher_fail());
        assert!(chaos.batcher_fail());
        assert!(!chaos.batcher_fail());
        assert_eq!(chaos.batcher_stall(), Some(Duration::from_millis(50)));
        assert_eq!(chaos.batcher_stall(), None);
        // Faults with zero budget never fire.
        assert!(!chaos.worker_panic());
        assert_eq!(chaos.stall_read(), None);
        assert_eq!(chaos.injected(), 3);
    }

    #[test]
    fn concurrent_takes_fire_exactly_n_times() {
        let chaos =
            std::sync::Arc::new(ServeChaos::new(ServePlan::parse("worker-panic=100").unwrap()));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = std::sync::Arc::clone(&chaos);
            handles.push(std::thread::spawn(move || {
                let mut fired = 0u64;
                for _ in 0..100 {
                    if c.worker_panic() {
                        fired += 1;
                    }
                }
                fired
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 100);
        assert_eq!(chaos.injected(), 100);
    }
}
