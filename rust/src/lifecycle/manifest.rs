//! Versioned snapshot manifest over a shard directory.
//!
//! `manifest.json` names the exact shard files (with size, row count, and
//! whole-file CRC) that make up one immutable snapshot of the store.
//! Because shard files are append-only — `shard-00000.bin`, `shard-00001.bin`,
//! … are written once and never rewritten — a manifest pins a *prefix* of
//! the directory, and a fit running against [`Manifest::store`] is
//! untouched by concurrent appends. The manifest itself advances by
//! write-then-rename, so readers observe either the old version or the new
//! one, never a torn document.

use super::LifecycleError;
use crate::data::shards::{crc32, decode_shard, ShardStore};
use crate::util::json::{jarr, jnum, jstr, Json};
use std::fs;
use std::path::Path;

/// Manifest file name inside a shard store directory.
pub const MANIFEST_FILE: &str = "manifest.json";
const FORMAT: &str = "rcca-manifest-v1";

/// One shard file as pinned by a manifest version.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardEntry {
    /// File name relative to the store directory (`shard-NNNNN.bin`).
    pub file: String,
    pub rows: usize,
    /// Whole-file length in bytes.
    pub bytes: usize,
    /// CRC-32 over the whole file (magic included — any mutation of an
    /// already-pinned shard is detected, not just payload damage).
    pub crc: u32,
}

/// An immutable snapshot of a shard store: a version number plus the exact
/// shard prefix it covers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// 1-based, bumped on every successful append.
    pub version: u64,
    pub dims_a: usize,
    pub dims_b: usize,
    pub shards: Vec<ShardEntry>,
}

/// Per-shard verification outcome from [`Manifest::verify`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardCheck {
    pub file: String,
    pub rows: usize,
    /// `None` = the file matches its manifest entry and decodes cleanly.
    pub error: Option<String>,
}

impl Manifest {
    /// Total rows across the pinned shards.
    pub fn rows(&self) -> usize {
        self.shards.iter().map(|s| s.rows).sum()
    }

    /// Content fingerprint of this snapshot: CRC-32 over the concatenated
    /// per-shard CRCs, in shard order. Two snapshots with the same hash
    /// pin byte-identical data.
    pub fn data_hash(&self) -> String {
        let mut bytes = Vec::with_capacity(self.shards.len() * 4);
        for s in &self.shards {
            bytes.extend_from_slice(&s.crc.to_le_bytes());
        }
        format!("{:08x}", crc32(&bytes))
    }

    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .shards
            .iter()
            .map(|s| {
                let mut e = Json::obj();
                e.set("file", jstr(&s.file))
                    .set("rows", jnum(s.rows as f64))
                    .set("bytes", jnum(s.bytes as f64))
                    .set("crc", jnum(s.crc as f64));
                e
            })
            .collect();
        let mut o = Json::obj();
        o.set("format", jstr(FORMAT))
            .set("version", jnum(self.version as f64))
            .set("dims_a", jnum(self.dims_a as f64))
            .set("dims_b", jnum(self.dims_b as f64))
            .set("rows", jnum(self.rows() as f64))
            .set("data_hash", jstr(&self.data_hash()))
            .set("shards", jarr(entries));
        o
    }

    /// Fail-closed deserialization: every field must be present and typed,
    /// the derived `rows` total must match, and `data_hash` must match —
    /// a truncated or hand-edited manifest is rejected whole.
    pub fn from_json(doc: &Json) -> Result<Manifest, LifecycleError> {
        let bad = LifecycleError::Manifest;
        let format = doc
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing 'format'".to_string()))?;
        if format != FORMAT {
            return Err(bad(format!(
                "unsupported manifest format '{format}' (expected '{FORMAT}')"
            )));
        }
        let get_usize = |d: &Json, k: &str| {
            d.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| bad(format!("missing or non-integer '{k}'")))
        };
        let version = get_usize(doc, "version")? as u64;
        if version == 0 {
            return Err(bad("version must be >= 1".to_string()));
        }
        let entries = doc
            .get("shards")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("missing 'shards' array".to_string()))?;
        let mut shards = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| bad(format!("shard {i}: missing 'file'")))?
                .to_string();
            let crc = get_usize(e, "crc")?;
            if crc > u32::MAX as usize {
                return Err(bad(format!("shard {i}: crc out of range")));
            }
            shards.push(ShardEntry {
                file,
                rows: get_usize(e, "rows")?,
                bytes: get_usize(e, "bytes")?,
                crc: crc as u32,
            });
        }
        let manifest = Manifest {
            version,
            dims_a: get_usize(doc, "dims_a")?,
            dims_b: get_usize(doc, "dims_b")?,
            shards,
        };
        if get_usize(doc, "rows")? != manifest.rows() {
            return Err(bad("'rows' disagrees with the shard entries".to_string()));
        }
        let hash = doc
            .get("data_hash")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing 'data_hash'".to_string()))?;
        if hash != manifest.data_hash() {
            return Err(bad("'data_hash' disagrees with the shard entries".to_string()));
        }
        Ok(manifest)
    }

    /// Load the store's current manifest. Any read or parse failure is an
    /// error and the on-disk file is left untouched — a fit holding an
    /// older [`Manifest`] keeps running against its pinned snapshot.
    pub fn load(dir: &Path) -> Result<Manifest, LifecycleError> {
        let path = dir.join(MANIFEST_FILE);
        let text = fs::read_to_string(&path)
            .map_err(|e| LifecycleError::Manifest(format!("read {}: {e}", path.display())))?;
        let doc = crate::util::json::parse(&text)
            .map_err(|e| LifecycleError::Manifest(format!("{}: {e}", path.display())))?;
        Manifest::from_json(&doc)
    }

    /// Atomically publish this manifest (write-then-rename): a crash mid-
    /// write leaves the previous version in place, never a torn document.
    pub fn save(&self, dir: &Path) -> Result<(), LifecycleError> {
        let tmp = dir.join(".manifest.json.tmp");
        fs::write(&tmp, self.to_json().to_string_pretty())?;
        fs::rename(&tmp, dir.join(MANIFEST_FILE))?;
        Ok(())
    }

    /// Build a version-1 manifest from an existing store directory
    /// (`meta.json` + shard files, as written by `repro gen`). Every shard
    /// is fully decoded; any corruption aborts the bootstrap.
    pub fn bootstrap(dir: &Path) -> Result<Manifest, LifecycleError> {
        let store = ShardStore::open(dir).map_err(LifecycleError::Manifest)?;
        let mut shards = Vec::with_capacity(store.shards);
        for i in 0..store.shards {
            let path = store.shard_path(i);
            let bytes = fs::read(&path)
                .map_err(|e| LifecycleError::Manifest(format!("read {}: {e}", path.display())))?;
            let chunk = decode_shard(&bytes)
                .map_err(|e| LifecycleError::Manifest(format!("{}: {e}", path.display())))?;
            if chunk.a.cols != store.dims_a || chunk.b.cols != store.dims_b {
                return Err(LifecycleError::Manifest(format!(
                    "{}: dims {}x{} disagree with meta.json ({}x{})",
                    path.display(),
                    chunk.a.cols,
                    chunk.b.cols,
                    store.dims_a,
                    store.dims_b
                )));
            }
            shards.push(ShardEntry {
                file: format!("shard-{i:05}.bin"),
                rows: chunk.rows(),
                bytes: bytes.len(),
                crc: crc32(&bytes),
            });
        }
        let manifest = Manifest {
            version: 1,
            dims_a: store.dims_a,
            dims_b: store.dims_b,
            shards,
        };
        if manifest.rows() != store.rows {
            return Err(LifecycleError::Manifest(format!(
                "shard rows sum to {}, meta.json says {}",
                manifest.rows(),
                store.rows
            )));
        }
        Ok(manifest)
    }

    /// A [`ShardStore`] pinned to exactly this snapshot's shard prefix.
    /// Built from the manifest's own counts — *not* from `meta.json`, which
    /// a concurrent ingest may already have advanced — so every pass over
    /// it reads the same immutable row set.
    pub fn store(&self, dir: &Path) -> ShardStore {
        ShardStore {
            dir: dir.to_path_buf(),
            shards: self.shards.len(),
            rows: self.rows(),
            dims_a: self.dims_a,
            dims_b: self.dims_b,
        }
    }

    /// Verify every pinned shard on disk against its entry: existence,
    /// length, whole-file CRC, full decode, and row count. Corruption is
    /// reported per shard rather than failing the sweep.
    pub fn verify(&self, dir: &Path) -> Vec<ShardCheck> {
        self.shards
            .iter()
            .map(|entry| {
                let err = check_entry(dir, entry, self.dims_a, self.dims_b).err();
                ShardCheck {
                    file: entry.file.clone(),
                    rows: entry.rows,
                    error: err,
                }
            })
            .collect()
    }
}

fn check_entry(
    dir: &Path,
    entry: &ShardEntry,
    dims_a: usize,
    dims_b: usize,
) -> Result<(), String> {
    let bytes = fs::read(dir.join(&entry.file)).map_err(|e| format!("unreadable: {e}"))?;
    if bytes.len() != entry.bytes {
        return Err(format!(
            "length changed: {} bytes on disk, manifest pinned {}",
            bytes.len(),
            entry.bytes
        ));
    }
    let crc = crc32(&bytes);
    if crc != entry.crc {
        return Err(format!(
            "crc mismatch: manifest {:08x}, on disk {crc:08x}",
            entry.crc
        ));
    }
    let chunk = decode_shard(&bytes)?;
    if chunk.rows() != entry.rows {
        return Err(format!(
            "row count changed: {} on disk, manifest pinned {}",
            chunk.rows(),
            entry.rows
        ));
    }
    if chunk.a.cols != dims_a || chunk.b.cols != dims_b {
        return Err(format!(
            "dims {}x{} disagree with manifest ({dims_a}x{dims_b})",
            chunk.a.cols, chunk.b.cols
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shards::ShardWriter;
    use crate::data::synthparl::{SynthParl, SynthParlConfig};

    fn write_store(dir: &Path, n: usize, seed: u64) {
        let d = SynthParl::generate(SynthParlConfig {
            n,
            dims: 32,
            topics: 4,
            words_per_topic: 8,
            background_words: 12,
            mean_len: 6.0,
            seed,
            ..Default::default()
        });
        let mut w = ShardWriter::create(dir, 64).unwrap();
        w.write_dataset(&d.a, &d.b).unwrap();
    }

    #[test]
    fn bootstrap_save_load_roundtrip() {
        let dir = std::env::temp_dir().join("rcca_manifest_roundtrip");
        let _ = fs::remove_dir_all(&dir);
        write_store(&dir, 200, 7);
        let m = Manifest::bootstrap(&dir).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.rows(), 200);
        assert_eq!(m.shards.len(), 4); // ceil(200/64)
        m.save(&dir).unwrap();
        let back = Manifest::load(&dir).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.data_hash(), m.data_hash());
        // The pinned store loads the same rows as a meta.json open.
        let pinned = m.store(&dir).load_all().unwrap();
        let via_meta = ShardStore::open(&dir).unwrap().load_all().unwrap();
        assert_eq!(pinned, via_meta);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_or_garbage_manifest_is_rejected() {
        let dir = std::env::temp_dir().join("rcca_manifest_truncated");
        let _ = fs::remove_dir_all(&dir);
        write_store(&dir, 100, 8);
        let m = Manifest::bootstrap(&dir).unwrap();
        m.save(&dir).unwrap();
        let full = fs::read_to_string(dir.join(MANIFEST_FILE)).unwrap();
        fs::write(dir.join(MANIFEST_FILE), &full[..full.len() / 2]).unwrap();
        assert!(matches!(
            Manifest::load(&dir),
            Err(LifecycleError::Manifest(_))
        ));
        fs::write(dir.join(MANIFEST_FILE), "{ not json").unwrap();
        assert!(Manifest::load(&dir).is_err());
        // Internal inconsistencies are rejected too (fail-closed fields).
        let mut doc = m.to_json();
        doc.set("rows", crate::util::json::jnum(1.0));
        assert!(Manifest::from_json(&doc).is_err());
        let mut doc = m.to_json();
        doc.set("data_hash", crate::util::json::jstr("00000000"));
        assert!(Manifest::from_json(&doc).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_flags_corruption_and_mutation() {
        let dir = std::env::temp_dir().join("rcca_manifest_verify");
        let _ = fs::remove_dir_all(&dir);
        write_store(&dir, 150, 9);
        let m = Manifest::bootstrap(&dir).unwrap();
        assert!(m.verify(&dir).iter().all(|c| c.error.is_none()));
        // Flip a byte in the middle of shard 1.
        let path = dir.join("shard-00001.bin");
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        fs::write(&path, &bytes).unwrap();
        let checks = m.verify(&dir);
        assert!(checks[0].error.is_none());
        assert!(checks[1].error.as_deref().unwrap().contains("crc"));
        // Delete shard 2: unreadable.
        fs::remove_file(dir.join("shard-00002.bin")).unwrap();
        let checks = m.verify(&dir);
        assert!(checks[2].error.as_deref().unwrap().contains("unreadable"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn data_hash_tracks_content() {
        let dir = std::env::temp_dir().join("rcca_manifest_hash");
        let _ = fs::remove_dir_all(&dir);
        write_store(&dir, 100, 10);
        let mut m = Manifest::bootstrap(&dir).unwrap();
        let h1 = m.data_hash();
        m.shards.push(ShardEntry {
            file: "shard-00009.bin".to_string(),
            rows: 10,
            bytes: 100,
            crc: 0xdeadbeef,
        });
        assert_ne!(m.data_hash(), h1);
        let _ = fs::remove_dir_all(&dir);
    }
}
