//! Drift scoring: how much worse does the live model explain a fresh batch
//! than the data it was fitted on?
//!
//! The score is model-relative, not distribution-relative: we evaluate the
//! fitted bases' canonical correlations **on the incoming batch** (one
//! in-memory pass over it) and compare the correlation sum against the sum
//! the model achieved at fit time. A batch drawn from the same joint
//! distribution scores near zero (sampling noise only); a batch whose
//! cross-view coupling has rotated away from the fitted subspace scores
//! high, because the old directions no longer line up.
//!
//! `score = max(0, (expected − observed) / expected)` — a dimensionless
//! relative drop in [0, 1]-ish territory, so one threshold works across
//! `k`, λ, and corpus scale.

use super::LifecycleError;
use crate::api::model::FittedModel;
use crate::cca::pass::InMemoryPass;
use crate::data::shards::TwoViewChunk;

/// One batch's drift evaluation against a fitted model.
#[derive(Debug, Clone)]
pub struct DriftScore {
    /// Rows in the scored batch.
    pub rows: usize,
    /// Correlation sum the model achieved at fit time.
    pub expected: f64,
    /// Correlation sum the same bases achieve on the fresh batch.
    pub observed: f64,
    /// Per-direction correlation drop (fit-time minus on-batch), length `k`.
    pub per_direction: Vec<f64>,
    /// Relative drop of the correlation sum, clamped at zero.
    pub score: f64,
}

/// Score one batch against the live model. Costs one in-memory pass over
/// the batch (cheap relative to any refit it might trigger).
///
/// Errors if the batch's dimensions disagree with the model's — a drifted
/// *vocabulary* is a schema change, not drift, and must not be folded into
/// a correlation score.
pub fn score_batch(
    model: &FittedModel,
    batch: &TwoViewChunk,
) -> Result<DriftScore, LifecycleError> {
    if batch.a.cols != model.da() || batch.b.cols != model.db() {
        return Err(LifecycleError::Refit(format!(
            "drift batch dims {}x{} disagree with model {}x{}",
            batch.a.cols,
            batch.b.cols,
            model.da(),
            model.db()
        )));
    }
    let mut pass = InMemoryPass::new(batch.clone());
    let obj = model.objective(&mut pass);
    let expected = model.sum_correlations();
    let observed = obj.sum_corr;
    let per_direction: Vec<f64> = model
        .correlations()
        .iter()
        .zip(obj.corrs.iter())
        .map(|(fit, fresh)| fit - fresh)
        .collect();
    let score = ((expected - observed) / expected.max(1e-12)).max(0.0);
    Ok(DriftScore {
        rows: batch.rows(),
        expected,
        observed,
        per_direction,
        score,
    })
}

/// Knobs for deciding when an observed score counts as drift.
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// Relative correlation drop at which the daemon triggers a refit.
    pub threshold: f64,
    /// Minimum batch rows before a score is trusted (small batches are
    /// noisy in exactly the direction that causes false alarms).
    pub min_rows: usize,
}

impl Default for DriftConfig {
    fn default() -> DriftConfig {
        DriftConfig {
            threshold: 0.25,
            min_rows: 1,
        }
    }
}

/// Stateful wrapper the daemon holds: remembers the last score so the
/// trigger decision and the metrics publication read the same evaluation.
#[derive(Debug)]
pub struct DriftMonitor {
    config: DriftConfig,
    last: Option<DriftScore>,
}

impl DriftMonitor {
    pub fn new(config: DriftConfig) -> DriftMonitor {
        DriftMonitor { config, last: None }
    }

    /// Score a batch and retain the result as the monitor's latest reading.
    pub fn observe(
        &mut self,
        model: &FittedModel,
        batch: &TwoViewChunk,
    ) -> Result<&DriftScore, LifecycleError> {
        let score = score_batch(model, batch)?;
        self.last = Some(score);
        Ok(self.last.as_ref().expect("just set"))
    }

    pub fn last(&self) -> Option<&DriftScore> {
        self.last.as_ref()
    }

    /// Does the latest reading cross the configured threshold?
    pub fn drifted(&self) -> bool {
        match &self.last {
            Some(s) => s.score >= self.config.threshold && s.rows >= self.config.min_rows,
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cca::horst::{Horst, HorstConfig};
    use crate::data::synthparl::{SynthParl, SynthParlConfig};

    fn corpus(n: usize, batch: u64, drift: f64) -> TwoViewChunk {
        let d = SynthParl::generate(SynthParlConfig {
            n,
            dims: 64,
            topics: 6,
            words_per_topic: 8,
            background_words: 16,
            mean_len: 8.0,
            seed: 41,
            batch,
            drift,
            ..Default::default()
        });
        TwoViewChunk { a: d.a, b: d.b }
    }

    fn fit(chunk: &TwoViewChunk) -> FittedModel {
        let mut engine = InMemoryPass::new(chunk.clone());
        let horst = Horst::new(HorstConfig {
            k: 4,
            lambda_a: 0.05,
            lambda_b: 0.05,
            pass_budget: 40,
            seed: 11,
            ..Default::default()
        });
        let (model, trace) = horst.fit(&mut engine).unwrap();
        FittedModel::new(model, 0.05, 0.05, "horst").with_trace(trace)
    }

    #[test]
    fn same_distribution_scores_low_drifted_scores_high() {
        let base = corpus(700, 0, 0.0);
        let model = fit(&base);
        let same = score_batch(&model, &corpus(350, 1, 0.0)).unwrap();
        let moved = score_batch(&model, &corpus(350, 1, 0.8)).unwrap();
        assert!(
            moved.score > same.score + 0.05,
            "drifted {:.4} vs same-dist {:.4}",
            moved.score,
            same.score
        );
        assert_eq!(same.per_direction.len(), 4);
        assert!(same.score >= 0.0 && moved.score.is_finite());
    }

    #[test]
    fn monitor_applies_threshold_and_min_rows() {
        let base = corpus(700, 0, 0.0);
        let model = fit(&base);
        let mut mon = DriftMonitor::new(DriftConfig {
            threshold: 0.0,
            min_rows: 1_000_000,
        });
        assert!(!mon.drifted());
        mon.observe(&model, &corpus(200, 1, 0.8)).unwrap();
        // Score clears the zero threshold but the batch is too small.
        assert!(!mon.drifted());
        mon = DriftMonitor::new(DriftConfig {
            threshold: 0.0,
            min_rows: 1,
        });
        mon.observe(&model, &corpus(200, 1, 0.8)).unwrap();
        assert!(mon.drifted());
        assert!(mon.last().unwrap().rows == 200);
    }

    #[test]
    fn dimension_mismatch_is_an_error_not_a_score() {
        let model = fit(&corpus(300, 0, 0.0));
        let wide = SynthParl::generate(SynthParlConfig {
            n: 100,
            dims: 96,
            topics: 6,
            words_per_topic: 8,
            background_words: 16,
            mean_len: 8.0,
            seed: 42,
            ..Default::default()
        });
        let err = score_batch(&model, &TwoViewChunk { a: wide.a, b: wide.b }).unwrap_err();
        assert!(format!("{err}").contains("dims"), "{err}");
    }
}
