//! The lifecycle daemon: watch the manifest, score fresh shards for drift,
//! warm-refit when drift (or a schedule) says so, hot-swap the result into
//! serving, and record the episode.
//!
//! The daemon is deliberately a *pull* loop around one synchronous,
//! fully-testable step: [`Daemon::tick`]. Each tick
//!
//! 1. loads the current [`Manifest`] (fail-closed: a torn manifest leaves
//!    the previous snapshot — and the served model — untouched);
//! 2. loads the served model document and derives the *baseline*: which
//!    snapshot version / shard prefix the model already reflects (from its
//!    embedded [`Provenance`] when present);
//! 3. drift-scores the shards appended since the baseline against the
//!    model's canonical correlations, publishing the score through
//!    [`ServeMetrics`];
//! 4. on drift ≥ threshold or a periodic schedule, warm-refits via
//!    [`Horst::fit_from`] from the served bases over the *pinned* snapshot
//!    (any engine spec: in-memory, sharded/streaming, cluster), overwrites
//!    the model document atomically (write-then-rename), pokes the reload
//!    hook, and appends an [`Episode`] to the audit ledger.
//!
//! Warm refits use no RNG ([`Horst::fit_from`] is deterministic), so a
//! refit over a fixed snapshot from a fixed model is bitwise-reproducible.

use super::audit::{AuditLedger, Episode, Retention};
use super::drift::{DriftConfig, DriftMonitor};
use super::manifest::Manifest;
use super::LifecycleError;
use crate::api::{Engine, FittedModel, Provenance, ShardedOpts};
use crate::cca::horst::{Horst, HorstConfig};
use crate::cca::pass::PassEngine;
use crate::data::shards::concat_chunks;
use crate::serve::{client, ModelRegistry, ServeMetrics};
use crate::telemetry;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// How a finished refit is swapped into serving.
pub enum ReloadHook {
    /// No serving process: the daemon only rewrites the model document.
    None,
    /// In-process registry (tests, embedded deployments): swap directly.
    Registry(Arc<ModelRegistry>),
    /// Remote serve process: `POST /admin/reload` against its admin port.
    Http(SocketAddr),
}

/// Daemon tunables. Defaults suit the synthparl-scale CI smoke; real
/// deployments mostly tune `drift_threshold`, `pass_budget`, and `engine`.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Relative correlation drop that triggers a refit.
    pub drift_threshold: f64,
    /// Fresh rows required before a drift score is trusted.
    pub min_new_rows: usize,
    /// Engine-pass budget per warm refit (`Horst` needs ≥ 2).
    pub pass_budget: usize,
    /// Relative objective tolerance for early refit convergence.
    pub tol: f64,
    /// Also refit on this wall-clock schedule, drift or not. The first
    /// tick after startup counts as due (a daemon restart re-baselines).
    pub refit_every: Option<Duration>,
    /// Engine spec for refits: `inmemory`, `native[?opts]` (both run over
    /// the manifest-pinned snapshot), or a full `cluster:<addrs>[?copts]`.
    pub engine: String,
    /// Audit-ledger retention.
    pub retention: Retention,
}

impl Default for DaemonConfig {
    fn default() -> DaemonConfig {
        DaemonConfig {
            drift_threshold: 0.25,
            min_new_rows: 1,
            pass_budget: 24,
            tol: 1e-3,
            refit_every: None,
            engine: "inmemory".to_string(),
            retention: Retention::default(),
        }
    }
}

/// What one [`Daemon::tick`] did.
#[derive(Debug)]
pub enum Tick {
    /// Nothing new under the manifest and no schedule due.
    Idle { version: u64 },
    /// Fresh shards were scored but did not trigger a refit.
    Observed { version: u64, score: f64 },
    /// A refit was due but the snapshot is unchanged — no-op, no swap.
    NoOp { version: u64 },
    /// A warm refit ran; the episode is what the ledger recorded.
    Refit(Episode),
}

/// The warm-refit daemon. Owns the drift monitor and the refit baseline;
/// the CLI (`repro daemon`) drives it in a poll loop, tests drive single
/// ticks.
pub struct Daemon {
    store_dir: PathBuf,
    model_path: PathBuf,
    config: DaemonConfig,
    ledger: AuditLedger,
    hook: ReloadHook,
    metrics: Option<Arc<ServeMetrics>>,
    monitor: DriftMonitor,
    /// (snapshot version, shard count) the served model reflects.
    baseline: Option<(u64, usize)>,
    last_refit_ms: Option<u64>,
}

impl Daemon {
    pub fn new(
        store_dir: &Path,
        model_path: &Path,
        audit_path: &Path,
        config: DaemonConfig,
    ) -> Daemon {
        let monitor = DriftMonitor::new(DriftConfig {
            threshold: config.drift_threshold,
            min_rows: config.min_new_rows,
        });
        let ledger = AuditLedger::open(audit_path, config.retention);
        Daemon {
            store_dir: store_dir.to_path_buf(),
            model_path: model_path.to_path_buf(),
            config,
            ledger,
            hook: ReloadHook::None,
            metrics: None,
            monitor,
            baseline: None,
            last_refit_ms: None,
        }
    }

    /// Swap refits into an in-process registry.
    pub fn with_registry(mut self, registry: Arc<ModelRegistry>) -> Daemon {
        self.hook = ReloadHook::Registry(registry);
        self
    }

    /// Swap refits into a remote serve process via `POST /admin/reload`.
    pub fn with_http_reload(mut self, addr: SocketAddr) -> Daemon {
        self.hook = ReloadHook::Http(addr);
        self
    }

    /// Publish drift scores and refit counts through serve metrics.
    pub fn with_metrics(mut self, metrics: Arc<ServeMetrics>) -> Daemon {
        self.metrics = Some(metrics);
        self
    }

    pub fn ledger(&self) -> &AuditLedger {
        &self.ledger
    }

    /// Latest drift evaluation, if any batch has been scored.
    pub fn last_drift(&self) -> Option<&super::drift::DriftScore> {
        self.monitor.last()
    }

    /// One synchronous lifecycle step; see the module docs for the phases.
    /// `now_unix_ms` is injected so tests and the CLI own the clock.
    pub fn tick(&mut self, now_unix_ms: u64) -> Result<Tick, LifecycleError> {
        let mut tick_span = telemetry::span("tick");
        let manifest = Manifest::load(&self.store_dir)?;
        tick_span.attr("version", manifest.version);
        if let Some((base_version, _)) = self.baseline {
            if manifest.version < base_version {
                return Err(LifecycleError::Manifest(format!(
                    "stale manifest: version {} regressed below the served baseline {}",
                    manifest.version, base_version
                )));
            }
        }
        let model = FittedModel::load(&self.model_path)
            .map_err(|e| LifecycleError::Refit(format!("load model: {e}")))?;

        let (base_version, base_shards) = match self.baseline {
            Some(b) => b,
            None => {
                let b = match model.provenance() {
                    Some(p) if p.snapshot_version <= manifest.version => {
                        (p.snapshot_version, p.shards.min(manifest.shards.len()))
                    }
                    // No provenance: assume the model reflects everything
                    // currently on disk and only react to future appends.
                    _ => (manifest.version, manifest.shards.len()),
                };
                self.baseline = Some(b);
                b
            }
        };

        // Score the shards appended since the baseline.
        let fresh_entries = &manifest.shards[base_shards.min(manifest.shards.len())..];
        let mut drift_score = 0.0;
        let mut drift_per_direction: Vec<f64> = Vec::new();
        if !fresh_entries.is_empty() {
            let store = manifest.store(&self.store_dir);
            let mut chunks = Vec::with_capacity(fresh_entries.len());
            for i in base_shards..manifest.shards.len() {
                chunks.push(store.load(i).map_err(LifecycleError::Manifest)?);
            }
            let batch = concat_chunks(&chunks);
            let score = self.monitor.observe(&model, &batch)?;
            drift_score = score.score;
            drift_per_direction = score.per_direction.clone();
            if let Some(m) = &self.metrics {
                m.add(&m.drift_batches, 1);
                m.drift_score_milli
                    .store((drift_score * 1000.0).round() as u64, Ordering::Relaxed);
                m.set_drift_per_direction(&drift_per_direction);
                if drift_score >= self.config.drift_threshold {
                    m.add(&m.drift_alerts, 1);
                }
            }
        }

        // Only this tick's evaluation can trigger: with nothing fresh the
        // monitor still remembers the score that caused the last refit.
        let drift_due = !fresh_entries.is_empty() && self.monitor.drifted();
        let periodic_due = match self.config.refit_every {
            Some(every) => {
                now_unix_ms >= self.last_refit_ms.unwrap_or(0) + every.as_millis() as u64
            }
            None => false,
        };
        if !drift_due && !periodic_due {
            return Ok(if fresh_entries.is_empty() {
                Tick::Idle { version: manifest.version }
            } else {
                Tick::Observed { version: manifest.version, score: drift_score }
            });
        }
        if manifest.version == base_version {
            // Refit due but the snapshot is unchanged: fit_from over the
            // same data from the same bases reproduces the same model, so
            // skip the fit and the swap entirely (no ledger entry either —
            // nothing about the served model changed).
            self.last_refit_ms = Some(now_unix_ms);
            return Ok(Tick::NoOp { version: manifest.version });
        }

        // Warm refit over the pinned snapshot. The episode id is claimed
        // up front so the refit span links to the ledger entry it will
        // produce (the id is re-derived from the file, so a failed refit
        // leaves no gap).
        let trigger = if drift_due { "drift" } else { "periodic" };
        let episode_id = self.ledger.next_episode()?;
        let mut refit_span = telemetry::span("refit");
        refit_span
            .attr("episode", episode_id)
            .attr("trigger", trigger)
            .attr("version", manifest.version);
        let mut engine = self.build_engine(&manifest)?;
        let before = model.objective(&mut engine).sum_corr;
        let start_passes = engine.passes();
        let horst = Horst::new(HorstConfig {
            k: model.k(),
            lambda_a: model.lambda_a,
            lambda_b: model.lambda_b,
            pass_budget: self.config.pass_budget,
            augment: true,
            seed: 0, // unused: fit_from never draws randomness
            tol: self.config.tol,
        });
        let (cca_model, trace) = horst
            .fit_from(&mut engine, model.xa().clone(), model.xb().clone())
            .map_err(|e| LifecycleError::Refit(format!("{e:#}")))?;
        let fit_passes = engine.passes() - start_passes;
        let sum_corr_after = cca_model.sum_correlations();
        let refit = FittedModel::new(cca_model, model.lambda_a, model.lambda_b, "horst+warm")
            .with_trace(trace)
            .with_fit_passes(fit_passes)
            .with_provenance(Provenance {
                snapshot_version: manifest.version,
                shards: manifest.shards.len(),
                rows: manifest.rows(),
                data_hash: manifest.data_hash(),
                trigger: trigger.to_string(),
            });

        // Atomic swap of the model document: the registry (or a remote
        // serve) only ever re-reads a fully-written file.
        let tmp = self.model_path.with_extension("json.refit-tmp");
        refit.save(&tmp).map_err(|e| LifecycleError::Refit(format!("save refit: {e}")))?;
        std::fs::rename(&tmp, &self.model_path)?;

        let (swapped, generation) = match &self.hook {
            ReloadHook::None => (false, 0),
            ReloadHook::Registry(reg) => {
                let snap = reg
                    .reload()
                    .map_err(|e| LifecycleError::Refit(format!("registry reload: {e}")))?;
                (true, snap.generation)
            }
            ReloadHook::Http(addr) => {
                let (status, body) = client::one_shot(*addr, "POST", "/admin/reload", None)
                    .map_err(|e| LifecycleError::Refit(format!("reload {addr}: {e}")))?;
                if status != 200 {
                    return Err(LifecycleError::Refit(format!(
                        "reload {addr}: status {status}: {body}"
                    )));
                }
                let generation = crate::util::json::parse(&body)
                    .ok()
                    .and_then(|doc| doc.get("generation").and_then(|g| g.as_usize()))
                    .ok_or_else(|| {
                        LifecycleError::Refit(format!("reload {addr}: no generation in {body}"))
                    })? as u64;
                (true, generation)
            }
        };

        let episode = Episode {
            episode: episode_id,
            trigger: trigger.to_string(),
            snapshot_version: manifest.version,
            drift_score,
            per_direction: drift_per_direction,
            passes: fit_passes,
            sum_corr_before: before,
            sum_corr_after,
            swapped,
            generation,
            unix_ms: now_unix_ms,
        };
        self.ledger.append(&episode)?;
        if let Some(m) = &self.metrics {
            m.add(&m.refits, 1);
        }
        self.baseline = Some((manifest.version, manifest.shards.len()));
        self.last_refit_ms = Some(now_unix_ms);
        Ok(Tick::Refit(episode))
    }

    /// Build the refit engine over the manifest-pinned snapshot.
    fn build_engine(&self, manifest: &Manifest) -> Result<Engine, LifecycleError> {
        let spec = self.config.engine.as_str();
        let bad = LifecycleError::Refit;
        if spec == "inmemory" {
            let chunk = manifest.store(&self.store_dir).load_all().map_err(bad)?;
            return Ok(Engine::in_memory(chunk));
        }
        if let Some(rest) = spec.strip_prefix("native") {
            let opts = match rest.strip_prefix('?') {
                Some(q) => ShardedOpts::parse_query(q).map_err(|e| bad(e.to_string()))?,
                None if rest.is_empty() => ShardedOpts::default(),
                None => return Err(bad(format!("bad engine spec '{spec}'"))),
            };
            let store = manifest.store(&self.store_dir);
            return Engine::sharded_store(store, opts).map_err(|e| bad(e.to_string()));
        }
        if spec.starts_with("cluster:") {
            // Workers serve whatever shard set they were started on; insist
            // it matches the snapshot so a refit never mixes versions.
            let engine = Engine::from_spec(spec).map_err(|e| bad(e.to_string()))?;
            let (n, da, db) = engine.shape();
            if (n, da, db) != (manifest.rows(), manifest.dims_a, manifest.dims_b) {
                return Err(bad(format!(
                    "cluster workers serve {n} rows ({da}x{db}) but snapshot v{} has {} rows \
                     ({}x{}) — restart workers on the new snapshot",
                    manifest.version,
                    manifest.rows(),
                    manifest.dims_a,
                    manifest.dims_b
                )));
            }
            return Ok(engine);
        }
        Err(bad(format!(
            "unknown daemon engine '{spec}' (expected inmemory | native[?opts] | cluster:<addrs>)"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shards::TwoViewChunk;
    use crate::data::synthparl::{SynthParl, SynthParlConfig};
    use crate::lifecycle::Ingestor;
    use std::fs;

    fn corpus(n: usize, batch: u64, drift: f64) -> TwoViewChunk {
        let d = SynthParl::generate(SynthParlConfig {
            n,
            dims: 64,
            topics: 6,
            words_per_topic: 8,
            background_words: 16,
            mean_len: 8.0,
            seed: 23,
            batch,
            drift,
            ..Default::default()
        });
        TwoViewChunk { a: d.a, b: d.b }
    }

    fn fit_and_save(ing: &Ingestor, dir: &Path, path: &Path) -> FittedModel {
        let chunk = ing.manifest().store(dir).load_all().unwrap();
        let mut engine = Engine::in_memory(chunk);
        let horst = Horst::new(HorstConfig {
            k: 4,
            lambda_a: 0.05,
            lambda_b: 0.05,
            pass_budget: 40,
            seed: 3,
            ..Default::default()
        });
        let (m, trace) = horst.fit(&mut engine).unwrap();
        let fitted = FittedModel::new(m, 0.05, 0.05, "horst")
            .with_trace(trace)
            .with_fit_passes(engine.passes())
            .with_provenance(Provenance {
                snapshot_version: ing.manifest().version,
                shards: ing.manifest().shards.len(),
                rows: ing.manifest().rows(),
                data_hash: ing.manifest().data_hash(),
                trigger: "cold".to_string(),
            });
        fitted.save(path).unwrap();
        fitted
    }

    fn setup(name: &str) -> (PathBuf, PathBuf, PathBuf, Ingestor) {
        let dir = std::env::temp_dir().join(name);
        let _ = fs::remove_dir_all(&dir);
        let store = dir.join("store");
        let mut ing = Ingestor::open(&store).unwrap();
        ing.append_chunk(&corpus(600, 0, 0.0)).unwrap();
        let model_path = dir.join("model.json");
        fit_and_save(&ing, &store, &model_path);
        (dir, store, model_path, ing)
    }

    #[test]
    fn idle_then_drift_refit_records_episode() {
        let (dir, store, model_path, mut ing) = setup("rcca_daemon_drift");
        let mut daemon = Daemon::new(
            &store,
            &model_path,
            &dir.join("audit.jsonl"),
            DaemonConfig {
                drift_threshold: 0.05,
                pass_budget: 24,
                ..Default::default()
            },
        );
        // Nothing new: idle, no ledger entry.
        assert!(matches!(daemon.tick(1000).unwrap(), Tick::Idle { version: 2 }));
        assert!(daemon.ledger().read().unwrap().is_empty());

        ing.append_chunk(&corpus(400, 1, 0.8)).unwrap();
        let tick = daemon.tick(2000).unwrap();
        let Tick::Refit(ep) = tick else {
            panic!("expected a refit, got {tick:?}");
        };
        assert_eq!(ep.trigger, "drift");
        assert_eq!(ep.snapshot_version, 3);
        assert!(ep.drift_score >= 0.05, "{}", ep.drift_score);
        assert!(ep.passes >= 2 && ep.passes <= 24, "{}", ep.passes);
        assert!(ep.sum_corr_after >= ep.sum_corr_before - 1e-9);
        assert!(!ep.swapped, "no hook configured");
        assert_eq!(daemon.ledger().read().unwrap().len(), 1);

        // The swapped-in document carries the new provenance.
        let refit = FittedModel::load(&model_path).unwrap();
        let p = refit.provenance().unwrap();
        assert_eq!((p.snapshot_version, &*p.trigger), (3, "drift"));
        assert_eq!(refit.solver(), "horst+warm");

        // Next tick: baseline advanced, nothing fresh → idle.
        assert!(matches!(daemon.tick(3000).unwrap(), Tick::Idle { version: 3 }));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn unchanged_snapshot_periodic_refit_is_a_noop() {
        let (dir, store, model_path, _ing) = setup("rcca_daemon_noop");
        let mut daemon = Daemon::new(
            &store,
            &model_path,
            &dir.join("audit.jsonl"),
            DaemonConfig {
                refit_every: Some(Duration::from_millis(0)),
                ..Default::default()
            },
        );
        let before = fs::read_to_string(&model_path).unwrap();
        assert!(matches!(daemon.tick(1000).unwrap(), Tick::NoOp { version: 2 }));
        // No swap, no episode, model document untouched.
        assert_eq!(fs::read_to_string(&model_path).unwrap(), before);
        assert!(daemon.ledger().read().unwrap().is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn small_batches_observe_without_refitting() {
        let (dir, store, model_path, mut ing) = setup("rcca_daemon_minrows");
        let mut daemon = Daemon::new(
            &store,
            &model_path,
            &dir.join("audit.jsonl"),
            DaemonConfig {
                drift_threshold: 0.0,
                min_new_rows: 10_000,
                ..Default::default()
            },
        );
        ing.append_chunk(&corpus(100, 1, 0.8)).unwrap();
        let tick = daemon.tick(1000).unwrap();
        assert!(matches!(tick, Tick::Observed { version: 3, .. }), "{tick:?}");
        assert!(daemon.ledger().read().unwrap().is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_manifest_fails_closed_and_recovers() {
        let (dir, store, model_path, _ing) = setup("rcca_daemon_torn");
        let mut daemon = Daemon::new(
            &store,
            &model_path,
            &dir.join("audit.jsonl"),
            DaemonConfig::default(),
        );
        assert!(matches!(daemon.tick(1000).unwrap(), Tick::Idle { .. }));
        let manifest_path = store.join(super::super::manifest::MANIFEST_FILE);
        let good = fs::read_to_string(&manifest_path).unwrap();
        fs::write(&manifest_path, &good[..good.len() / 2]).unwrap();
        assert!(matches!(daemon.tick(2000).unwrap_err(), LifecycleError::Manifest(_)));
        // The model document was never touched; restoring the manifest
        // resumes the loop.
        fs::write(&manifest_path, good).unwrap();
        assert!(matches!(daemon.tick(3000).unwrap(), Tick::Idle { .. }));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn registry_hook_swaps_generation() {
        let (dir, store, model_path, mut ing) = setup("rcca_daemon_registry");
        let registry = Arc::new(ModelRegistry::open(&model_path).unwrap());
        let metrics = Arc::new(ServeMetrics::new());
        let mut daemon = Daemon::new(
            &store,
            &model_path,
            &dir.join("audit.jsonl"),
            DaemonConfig {
                drift_threshold: 0.05,
                ..Default::default()
            },
        )
        .with_registry(Arc::clone(&registry))
        .with_metrics(Arc::clone(&metrics));

        ing.append_chunk(&corpus(400, 1, 0.8)).unwrap();
        let Tick::Refit(ep) = daemon.tick(5000).unwrap() else {
            panic!("expected refit");
        };
        assert!(ep.swapped);
        assert_eq!(ep.generation, 2);
        assert_eq!(registry.generation(), 2);
        let meta = registry.metadata();
        let prov = meta.get("provenance").expect("metadata has provenance");
        assert_eq!(prov.get("snapshot_version").unwrap().as_usize(), Some(3));
        assert_eq!(metrics.drift_batches.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.drift_alerts.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.refits.load(Ordering::Relaxed), 1);
        assert!(metrics.drift_score_milli.load(Ordering::Relaxed) >= 50);
        let _ = fs::remove_dir_all(&dir);
    }
}
