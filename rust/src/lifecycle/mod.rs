//! `rcca::lifecycle` — continuous ingest, drift monitoring, and warm refit:
//! the closed loop over everything the crate already does once.
//!
//! The paper's headline property — accurate CCA in as few as two data
//! passes — makes *refitting* cheap enough to be the answer to streaming
//! data. This module turns fit + serve + cluster into that loop:
//!
//! * [`manifest`] — a versioned, atomically-advanced snapshot manifest over
//!   a shard directory. Fits run against a manifest-pinned [`ShardStore`]
//!   prefix, so a running pass never sees a half-written shard set.
//! * [`ingest`] — validate-then-append: a shard is CRC/structure-checked
//!   *before* anything touches disk, written under a temp name, renamed,
//!   and only then does the manifest version advance.
//! * [`drift`] — scores an incoming batch against the live model's
//!   canonical correlations (relative drop of the batch objective).
//! * [`daemon`] — the loop: watch the manifest, score fresh shards, and on
//!   drift ≥ threshold (or a periodic schedule) warm-refit via
//!   `Horst::fit_from` from the served bases, atomically overwrite the
//!   model document, and hot-swap it into the serve registry.
//! * [`audit`] — append-only episode ledger with an explicit retention
//!   policy; deletion is never silent (a retention marker keeps the count).
//!
//! [`ShardStore`]: crate::data::shards::ShardStore

pub mod audit;
pub mod daemon;
pub mod drift;
pub mod ingest;
pub mod manifest;

pub use audit::{AuditLedger, Episode, Retention};
pub use daemon::{Daemon, DaemonConfig, ReloadHook, Tick};
pub use drift::{score_batch, DriftConfig, DriftMonitor, DriftScore};
pub use ingest::Ingestor;
pub use manifest::{Manifest, ShardCheck, ShardEntry, MANIFEST_FILE};

use std::fmt;

/// Typed failures of the lifecycle loop. Every variant is fail-closed: a
/// manifest that does not parse leaves the previous snapshot untouched, a
/// shard that does not validate is never written, a refit that errors
/// leaves the served model document as it was.
#[derive(Debug)]
pub enum LifecycleError {
    /// Manifest missing, malformed, stale, or inconsistent with the store.
    Manifest(String),
    /// Shard rejected at ingest (validation happens before any write).
    Ingest(String),
    /// Audit ledger unreadable or unwritable.
    Audit(String),
    /// Warm refit, engine construction, or model swap failed.
    Refit(String),
    Io(std::io::Error),
}

impl fmt::Display for LifecycleError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LifecycleError::Manifest(m) => write!(f, "manifest: {m}"),
            LifecycleError::Ingest(m) => write!(f, "ingest: {m}"),
            LifecycleError::Audit(m) => write!(f, "audit: {m}"),
            LifecycleError::Refit(m) => write!(f, "refit: {m}"),
            LifecycleError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for LifecycleError {}

impl From<std::io::Error> for LifecycleError {
    fn from(e: std::io::Error) -> LifecycleError {
        LifecycleError::Io(e)
    }
}
