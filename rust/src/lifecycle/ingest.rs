//! Validate-then-append shard ingest under the snapshot manifest.
//!
//! Ordering discipline (the whole point of this module):
//!
//! 1. the incoming shard is CRC-checked and **fully decoded** — and its
//!    dimensions checked against the store — before anything touches disk;
//! 2. the shard file is written under a temp name and renamed into place
//!    (a crash never leaves a torn file under the final name);
//! 3. `meta.json` is rewritten (same write-then-rename);
//! 4. only then does the manifest advance to `version + 1`.
//!
//! A corrupt shard therefore fails at step 1 with the store byte-identical
//! to before the call, and a reader holding the previous [`Manifest`]
//! never observes any intermediate state: its pinned prefix is immutable.

use super::manifest::{Manifest, ShardEntry, MANIFEST_FILE};
use super::LifecycleError;
use crate::data::shards::{crc32, decode_shard, encode_shard, TwoViewChunk};
use crate::util::json::{jnum, jstr, Json};
use std::fs;
use std::path::{Path, PathBuf};

/// Appends shards to a store, advancing the snapshot manifest atomically
/// after each successful append.
#[derive(Debug)]
pub struct Ingestor {
    dir: PathBuf,
    manifest: Manifest,
}

impl Ingestor {
    /// Open a store for ingest. Three cases:
    /// * a manifest exists — load it (fail-closed);
    /// * shards exist but no manifest (`repro gen` output) — bootstrap a
    ///   version-1 manifest from `meta.json` + full shard validation;
    /// * the directory is empty or missing — create an empty version-1
    ///   store whose dimensions are adopted from the first appended shard.
    pub fn open(dir: &Path) -> Result<Ingestor, LifecycleError> {
        let manifest = if dir.join(MANIFEST_FILE).exists() {
            Manifest::load(dir)?
        } else if dir.join("meta.json").exists() {
            let m = Manifest::bootstrap(dir)?;
            m.save(dir)?;
            m
        } else {
            fs::create_dir_all(dir)?;
            let m = Manifest {
                version: 1,
                dims_a: 0,
                dims_b: 0,
                shards: Vec::new(),
            };
            write_meta(dir, &m)?;
            m.save(dir)?;
            m
        };
        Ok(Ingestor {
            dir: dir.to_path_buf(),
            manifest,
        })
    }

    /// The snapshot this ingestor last published.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Encode and append one row-aligned chunk as a new shard.
    pub fn append_chunk(&mut self, chunk: &TwoViewChunk) -> Result<&Manifest, LifecycleError> {
        let bytes = encode_shard(chunk);
        self.append_shard_bytes(&bytes)
    }

    /// Append an already-encoded shard file from elsewhere on disk.
    pub fn append_shard_file(&mut self, path: &Path) -> Result<&Manifest, LifecycleError> {
        let bytes = fs::read(path)
            .map_err(|e| LifecycleError::Ingest(format!("read {}: {e}", path.display())))?;
        self.append_shard_bytes(&bytes)
    }

    /// Append one encoded shard. Validation (CRC + full structural decode
    /// + dimension check) happens before any write; on error the store and
    /// manifest are byte-identical to before the call.
    pub fn append_shard_bytes(&mut self, bytes: &[u8]) -> Result<&Manifest, LifecycleError> {
        let chunk = decode_shard(bytes)
            .map_err(|e| LifecycleError::Ingest(format!("rejected shard: {e}")))?;
        if chunk.rows() == 0 {
            return Err(LifecycleError::Ingest("rejected shard: zero rows".to_string()));
        }
        let empty = self.manifest.shards.is_empty() && self.manifest.dims_a == 0;
        if !empty && (chunk.a.cols != self.manifest.dims_a || chunk.b.cols != self.manifest.dims_b)
        {
            return Err(LifecycleError::Ingest(format!(
                "rejected shard: dims {}x{} disagree with the store ({}x{})",
                chunk.a.cols, chunk.b.cols, self.manifest.dims_a, self.manifest.dims_b
            )));
        }

        let index = self.manifest.shards.len();
        let file = format!("shard-{index:05}.bin");
        let tmp = self.dir.join(format!(".shard-{index:05}.tmp"));
        fs::File::create(&tmp).and_then(|mut f| {
            use std::io::Write;
            f.write_all(bytes)
        })?;
        fs::rename(&tmp, self.dir.join(&file))?;

        if empty {
            self.manifest.dims_a = chunk.a.cols;
            self.manifest.dims_b = chunk.b.cols;
        }
        self.manifest.shards.push(ShardEntry {
            file,
            rows: chunk.rows(),
            bytes: bytes.len(),
            crc: crc32(bytes),
        });
        write_meta(&self.dir, &self.manifest)?;
        self.manifest.version += 1;
        self.manifest.save(&self.dir)?;
        Ok(&self.manifest)
    }
}

/// Rewrite `meta.json` (write-then-rename) so plain [`ShardStore::open`]
/// consumers — workers, `repro transform --shards`, the engine specs —
/// keep working on an ingest-managed store.
///
/// [`ShardStore::open`]: crate::data::shards::ShardStore::open
fn write_meta(dir: &Path, manifest: &Manifest) -> Result<(), LifecycleError> {
    let rows_per_shard = manifest.shards.iter().map(|s| s.rows).max().unwrap_or(0);
    let mut meta = Json::obj();
    meta.set("format", jstr("rcca-shards-v1"))
        .set("shards", jnum(manifest.shards.len() as f64))
        .set("rows", jnum(manifest.rows() as f64))
        .set("dims_a", jnum(manifest.dims_a as f64))
        .set("dims_b", jnum(manifest.dims_b as f64))
        .set("rows_per_shard", jnum(rows_per_shard as f64));
    let tmp = dir.join(".meta.json.tmp");
    fs::write(&tmp, meta.to_string_pretty())?;
    fs::rename(&tmp, dir.join("meta.json"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shards::ShardStore;
    use crate::data::synthparl::{SynthParl, SynthParlConfig};

    fn chunk(n: usize, seed: u64) -> TwoViewChunk {
        let d = SynthParl::generate(SynthParlConfig {
            n,
            dims: 32,
            topics: 4,
            words_per_topic: 8,
            background_words: 12,
            mean_len: 6.0,
            seed,
            ..Default::default()
        });
        TwoViewChunk { a: d.a, b: d.b }
    }

    #[test]
    fn append_advances_version_and_pins_old_snapshots() {
        let dir = std::env::temp_dir().join("rcca_ingest_append");
        let _ = fs::remove_dir_all(&dir);
        let mut ing = Ingestor::open(&dir).unwrap();
        assert_eq!(ing.manifest().version, 1);
        ing.append_chunk(&chunk(80, 1)).unwrap();
        let v2 = ing.manifest().clone();
        assert_eq!(v2.version, 2);
        assert_eq!(v2.rows(), 80);

        // A reader pinned to v2 sees 80 rows forever, even after appends.
        ing.append_chunk(&chunk(50, 2)).unwrap();
        assert_eq!(ing.manifest().version, 3);
        assert_eq!(ing.manifest().rows(), 130);
        let pinned = v2.store(&dir).load_all().unwrap();
        assert_eq!(pinned.rows(), 80);
        // meta.json tracks the full store for plain consumers.
        let store = ShardStore::open(&dir).unwrap();
        assert_eq!((store.shards, store.rows), (2, 130));
        assert!(Manifest::load(&dir)
            .unwrap()
            .verify(&dir)
            .iter()
            .all(|c| c.error.is_none()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_resumes_from_the_published_manifest() {
        let dir = std::env::temp_dir().join("rcca_ingest_reopen");
        let _ = fs::remove_dir_all(&dir);
        let mut ing = Ingestor::open(&dir).unwrap();
        ing.append_chunk(&chunk(60, 3)).unwrap();
        drop(ing);
        let mut again = Ingestor::open(&dir).unwrap();
        assert_eq!(again.manifest().version, 2);
        again.append_chunk(&chunk(60, 4)).unwrap();
        assert_eq!(again.manifest().shards.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn bootstrap_from_gen_output() {
        let dir = std::env::temp_dir().join("rcca_ingest_bootstrap");
        let _ = fs::remove_dir_all(&dir);
        let c = chunk(120, 5);
        let mut w = crate::data::shards::ShardWriter::create(&dir, 50).unwrap();
        w.write_dataset(&c.a, &c.b).unwrap();
        let ing = Ingestor::open(&dir).unwrap();
        assert_eq!(ing.manifest().version, 1);
        assert_eq!(ing.manifest().shards.len(), 3);
        assert_eq!(ing.manifest().rows(), 120);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_shard_rejected_without_advancing() {
        let dir = std::env::temp_dir().join("rcca_ingest_corrupt");
        let _ = fs::remove_dir_all(&dir);
        let mut ing = Ingestor::open(&dir).unwrap();
        ing.append_chunk(&chunk(70, 6)).unwrap();
        let before = fs::read_to_string(dir.join(MANIFEST_FILE)).unwrap();

        let mut bytes = encode_shard(&chunk(30, 7));
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        let err = ing.append_shard_bytes(&bytes).unwrap_err();
        assert!(matches!(err, LifecycleError::Ingest(_)), "{err}");

        // Nothing advanced, nothing written.
        assert_eq!(ing.manifest().version, 2);
        assert_eq!(fs::read_to_string(dir.join(MANIFEST_FILE)).unwrap(), before);
        assert!(!dir.join("shard-00001.bin").exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let dir = std::env::temp_dir().join("rcca_ingest_dims");
        let _ = fs::remove_dir_all(&dir);
        let mut ing = Ingestor::open(&dir).unwrap();
        ing.append_chunk(&chunk(40, 8)).unwrap();
        let wide = SynthParl::generate(SynthParlConfig {
            n: 40,
            dims: 64,
            topics: 4,
            words_per_topic: 8,
            background_words: 12,
            mean_len: 6.0,
            seed: 9,
            ..Default::default()
        });
        let err = ing
            .append_chunk(&TwoViewChunk { a: wide.a, b: wide.b })
            .unwrap_err();
        assert!(format!("{err}").contains("dims"), "{err}");
        assert_eq!(ing.manifest().version, 2);
        let _ = fs::remove_dir_all(&dir);
    }
}
