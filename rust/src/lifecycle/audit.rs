//! Append-only refit episode ledger (JSONL) with explicit retention.
//!
//! Every daemon refit — drift-triggered or periodic — appends exactly one
//! compact-JSON line. The file is the system of record for "what happened
//! to the served model and why": snapshot version, drift score, passes
//! spent, correlation before/after, and the registry generation swapped in.
//!
//! Retention is explicit and never silent: when the episode count exceeds
//! [`Retention::max_records`], the ledger is compacted (write-then-rename)
//! down to the newest `max_records` episodes plus a single
//! `{"kind":"retention","dropped":N}` marker carrying the cumulative count
//! of episodes ever dropped — so episode numbering stays monotone across
//! compactions and an auditor can see that (and how much) history is gone.

use super::LifecycleError;
use crate::util::json::{jarr, jnum, jstr, parse, Json};
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

/// How much episode history the ledger keeps on disk.
#[derive(Debug, Clone, Copy, Default)]
pub struct Retention {
    /// Newest episodes kept after compaction; `0` means keep everything.
    pub max_records: usize,
}

/// One recorded refit episode.
#[derive(Debug, Clone, PartialEq)]
pub struct Episode {
    /// Monotone ledger-wide id (survives retention compaction).
    pub episode: u64,
    /// `"drift"` or `"periodic"`.
    pub trigger: String,
    /// Manifest version the refit ran against.
    pub snapshot_version: u64,
    /// Drift score that (if trigger is `"drift"`) fired the refit.
    pub drift_score: f64,
    /// Per-direction drift deltas behind `drift_score` (empty for
    /// periodic refits with nothing scored, and for pre-telemetry ledgers).
    pub per_direction: Vec<f64>,
    /// Engine passes the warm refit consumed.
    pub passes: usize,
    /// Old model's correlation sum evaluated on the new snapshot.
    pub sum_corr_before: f64,
    /// Refit model's correlation sum on the same snapshot.
    pub sum_corr_after: f64,
    /// Whether a serve hot-swap was performed (false for `--reload` none).
    pub swapped: bool,
    /// Registry generation after the swap (0 when `swapped` is false).
    pub generation: u64,
    /// Swap timestamp, milliseconds since the Unix epoch.
    pub unix_ms: u64,
}

impl Episode {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("kind", jstr("episode"))
            .set("episode", jnum(self.episode as f64))
            .set("trigger", jstr(&self.trigger))
            .set("snapshot_version", jnum(self.snapshot_version as f64))
            .set("drift_score", jnum(self.drift_score))
            .set(
                "per_direction",
                jarr(self.per_direction.iter().map(|&d| jnum(d)).collect()),
            )
            .set("passes", jnum(self.passes as f64))
            .set("sum_corr_before", jnum(self.sum_corr_before))
            .set("sum_corr_after", jnum(self.sum_corr_after))
            .set("swapped", Json::Bool(self.swapped))
            .set("generation", jnum(self.generation as f64))
            .set("unix_ms", jnum(self.unix_ms as f64));
        o
    }

    pub fn from_json(doc: &Json) -> Result<Episode, LifecycleError> {
        let bad = LifecycleError::Audit;
        let field = |k: &str| {
            doc.get(k)
                .ok_or_else(|| LifecycleError::Audit(format!("episode missing `{k}`")))
        };
        let num = |k: &str| {
            field(k)?
                .as_usize()
                .ok_or_else(|| LifecycleError::Audit(format!("episode `{k}` not a count")))
        };
        let float = |k: &str| {
            field(k)?
                .as_f64()
                .ok_or_else(|| LifecycleError::Audit(format!("episode `{k}` not a number")))
        };
        let trigger = field("trigger")?
            .as_str()
            .ok_or_else(|| bad("episode `trigger` not a string".to_string()))?
            .to_string();
        let swapped = field("swapped")?
            .as_bool()
            .ok_or_else(|| bad("episode `swapped` not a bool".to_string()))?;
        // Absent in ledgers written before per-direction drift export.
        let per_direction = match doc.get("per_direction") {
            Some(v) => v
                .as_arr()
                .ok_or_else(|| bad("episode `per_direction` not an array".to_string()))?
                .iter()
                .map(|d| {
                    d.as_f64().ok_or_else(|| {
                        bad("episode `per_direction` entry not a number".to_string())
                    })
                })
                .collect::<Result<Vec<f64>, _>>()?,
            None => Vec::new(),
        };
        Ok(Episode {
            episode: num("episode")? as u64,
            trigger,
            snapshot_version: num("snapshot_version")? as u64,
            drift_score: float("drift_score")?,
            per_direction,
            passes: num("passes")?,
            sum_corr_before: float("sum_corr_before")?,
            sum_corr_after: float("sum_corr_after")?,
            swapped,
            generation: num("generation")? as u64,
            unix_ms: num("unix_ms")? as u64,
        })
    }
}

/// Append-only JSONL ledger of refit episodes.
#[derive(Debug)]
pub struct AuditLedger {
    path: PathBuf,
    retention: Retention,
}

impl AuditLedger {
    pub fn open(path: &Path, retention: Retention) -> AuditLedger {
        AuditLedger {
            path: path.to_path_buf(),
            retention,
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Parse the ledger: retained episodes plus the cumulative count of
    /// episodes dropped by earlier retention compactions. Fail-closed: a
    /// line that is neither a valid episode nor a retention marker is an
    /// error, not a skip — a half-written ledger should be noticed.
    fn read_lines(&self) -> Result<(Vec<Episode>, u64), LifecycleError> {
        if !self.path.exists() {
            return Ok((Vec::new(), 0));
        }
        let text = fs::read_to_string(&self.path)
            .map_err(|e| LifecycleError::Audit(format!("read {}: {e}", self.path.display())))?;
        let mut episodes = Vec::new();
        let mut dropped: u64 = 0;
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let doc = parse(line)
                .map_err(|e| LifecycleError::Audit(format!("ledger line {}: {e}", i + 1)))?;
            match doc.get("kind").and_then(|k| k.as_str()) {
                Some("episode") => episodes.push(Episode::from_json(&doc)?),
                Some("retention") => {
                    let d = doc.get("dropped").and_then(|d| d.as_usize()).ok_or_else(|| {
                        LifecycleError::Audit(format!("ledger line {}: bad retention", i + 1))
                    })?;
                    dropped += d as u64;
                }
                _ => {
                    return Err(LifecycleError::Audit(format!(
                        "ledger line {}: unknown kind",
                        i + 1
                    )))
                }
            }
        }
        Ok((episodes, dropped))
    }

    /// All retained episodes, oldest first.
    pub fn read(&self) -> Result<Vec<Episode>, LifecycleError> {
        Ok(self.read_lines()?.0)
    }

    /// The id the next appended episode should carry: one past the newest
    /// retained episode, accounting for compacted-away history.
    pub fn next_episode(&self) -> Result<u64, LifecycleError> {
        let (episodes, dropped) = self.read_lines()?;
        Ok(episodes.last().map(|e| e.episode).unwrap_or(dropped) + 1)
    }

    /// Append one episode, then enforce retention if the file now holds
    /// more than `max_records` episodes.
    pub fn append(&self, episode: &Episode) -> Result<(), LifecycleError> {
        if let Some(parent) = self.path.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)?;
            }
        }
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .map_err(|e| LifecycleError::Audit(format!("open {}: {e}", self.path.display())))?;
        writeln!(f, "{}", episode.to_json().to_string_compact())
            .and_then(|()| f.flush())
            .map_err(|e| LifecycleError::Audit(format!("append: {e}")))?;
        drop(f);

        let max = self.retention.max_records;
        if max == 0 {
            return Ok(());
        }
        let (episodes, dropped) = self.read_lines()?;
        if episodes.len() <= max {
            return Ok(());
        }
        let cut = episodes.len() - max;
        let total_dropped = dropped + cut as u64;
        let mut out = String::new();
        let mut marker = Json::obj();
        marker
            .set("kind", jstr("retention"))
            .set("dropped", jnum(total_dropped as f64));
        out.push_str(&marker.to_string_compact());
        out.push('\n');
        for e in &episodes[cut..] {
            out.push_str(&e.to_json().to_string_compact());
            out.push('\n');
        }
        let tmp = self.path.with_extension("jsonl.tmp");
        fs::write(&tmp, out)
            .and_then(|()| fs::rename(&tmp, &self.path))
            .map_err(|e| LifecycleError::Audit(format!("compact: {e}")))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn episode(id: u64) -> Episode {
        Episode {
            episode: id,
            trigger: "drift".to_string(),
            snapshot_version: id + 1,
            drift_score: 0.3,
            per_direction: vec![0.25, 0.05],
            passes: 8,
            sum_corr_before: 1.2,
            sum_corr_after: 2.4,
            swapped: true,
            generation: id,
            unix_ms: 1_700_000_000_000 + id,
        }
    }

    #[test]
    fn roundtrip_and_monotone_ids() {
        let dir = std::env::temp_dir().join("rcca_audit_roundtrip");
        let _ = fs::remove_dir_all(&dir);
        let ledger = AuditLedger::open(&dir.join("audit.jsonl"), Retention::default());
        assert_eq!(ledger.next_episode().unwrap(), 1);
        for id in 1..=3 {
            let mut e = episode(id);
            e.episode = ledger.next_episode().unwrap();
            ledger.append(&e).unwrap();
        }
        let got = ledger.read().unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[2].episode, 3);
        assert_eq!(got[0], episode(1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn retention_compacts_but_keeps_the_count_and_numbering() {
        let dir = std::env::temp_dir().join("rcca_audit_retention");
        let _ = fs::remove_dir_all(&dir);
        let path = dir.join("audit.jsonl");
        let ledger = AuditLedger::open(&path, Retention { max_records: 2 });
        for _ in 0..5 {
            let mut e = episode(0);
            e.episode = ledger.next_episode().unwrap();
            ledger.append(&e).unwrap();
        }
        let got = ledger.read().unwrap();
        assert_eq!(
            got.iter().map(|e| e.episode).collect::<Vec<_>>(),
            vec![4, 5]
        );
        // Numbering continues past the compacted history.
        assert_eq!(ledger.next_episode().unwrap(), 6);
        let text = fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"kind\":\"retention\""), "{text}");
        assert!(text.contains("\"dropped\":3"), "{text}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn garbage_line_is_an_error() {
        let dir = std::env::temp_dir().join("rcca_audit_garbage");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("audit.jsonl");
        fs::write(&path, "{\"kind\":\"mystery\"}\n").unwrap();
        let ledger = AuditLedger::open(&path, Retention::default());
        assert!(ledger.read().is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
