//! Serve load benchmark: an in-process load generator driving `rcca::serve`
//! over real localhost sockets.
//!
//! Fits a small model, starts the server on an ephemeral port, then hammers
//! `POST /v1/transform` from several keep-alive client threads — ≥ 10k
//! requests total (1k in `RCCA_BENCH_SHORT` smoke mode), zero tolerated
//! failures. Reports throughput and p50/p99 latency (plus the batcher's
//! fusion stats) both to stdout and to `BENCH_serve.json` at the repo root
//! for the cross-PR perf trajectory.

use rcca::api::{Cca, Engine};
use rcca::bench::{short_mode, write_bench_json};
use rcca::data::synthparl::{SynthParl, SynthParlConfig};
use rcca::data::TwoViewChunk;
use rcca::serve::{proto, HttpClient, Server, ServerConfig, View};
use rcca::util::json::{jnum, jstr, Json};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const CLIENT_THREADS: usize = 4;
const DISTINCT_BODIES: usize = 64;

/// 12k total (≥ 10k floor) in full mode; 1k in CI smoke mode.
fn requests_per_client() -> usize {
    if short_mode() {
        250
    } else {
        3000
    }
}

fn main() {
    // A serving-shaped corpus: small enough to fit in seconds, wide enough
    // that a transform does real sparse work.
    let d = SynthParl::generate(SynthParlConfig {
        n: 400,
        dims: 64,
        topics: 6,
        words_per_topic: 10,
        background_words: 24,
        mean_len: 8.0,
        seed: 2026,
        ..Default::default()
    });
    let chunk = TwoViewChunk { a: d.a, b: d.b };
    let mut eng = Engine::in_memory(chunk.clone());
    let model = Cca::builder()
        .k(4)
        .oversample(12)
        .power_iters(1)
        .lambda(0.05, 0.05)
        .seed(9)
        .fit(&mut eng)
        .expect("fit bench model");

    let dir = std::env::temp_dir().join("rcca_bench_serve");
    let _ = std::fs::remove_dir_all(&dir);
    let model_path = dir.join("model.json");
    model.save(&model_path).expect("save bench model");

    let cfg = ServerConfig {
        // Two more threads than concurrent clients, so the auto transform
        // concurrency cap (threads - 2) never 429s the bench loop.
        threads: 6,
        queue_capacity: 256,
        max_batch_rows: 128,
        read_timeout: Duration::from_secs(10),
        ..Default::default()
    };
    let server = Server::bind(&model_path, "127.0.0.1:0", cfg).expect("bind server");
    let addr = server.local_addr();
    let handle = server.handle();
    let metrics = server.metrics();
    let server_thread = std::thread::spawn(move || server.run());

    // Pre-render request bodies (single rows, both views) so the measured
    // loop is the server round-trip, not client-side JSON assembly.
    let bodies: Arc<Vec<String>> = Arc::new(
        (0..DISTINCT_BODIES)
            .map(|i| {
                let view = if i % 3 == 0 { View::B } else { View::A };
                let src = match view {
                    View::A => &chunk.a,
                    View::B => &chunk.b,
                };
                proto::transform_request(view, &src.slice_rows(i, i + 1)).to_string_compact()
            })
            .collect(),
    );

    let per_client = requests_per_client();
    println!("# serve load: {CLIENT_THREADS} clients x {per_client} requests against {addr}");
    let failed = Arc::new(AtomicU64::new(0));
    let wall = Instant::now();
    let mut workers = Vec::new();
    for t in 0..CLIENT_THREADS {
        let bodies = Arc::clone(&bodies);
        let failed = Arc::clone(&failed);
        workers.push(std::thread::spawn(move || {
            let mut latencies = Vec::with_capacity(per_client);
            let mut client = HttpClient::connect(addr).expect("connect load client");
            for i in 0..per_client {
                let body = &bodies[(t + i * CLIENT_THREADS) % bodies.len()];
                let started = Instant::now();
                match client.post("/v1/transform", body) {
                    Ok((200, resp)) if resp.contains("projections") => {
                        latencies.push(started.elapsed().as_secs_f64());
                    }
                    Ok((status, resp)) => {
                        failed.fetch_add(1, Ordering::SeqCst);
                        eprintln!("request failed: status {status}: {resp}");
                    }
                    Err(e) => {
                        failed.fetch_add(1, Ordering::SeqCst);
                        eprintln!("request errored: {e}");
                        // Transport is gone; reconnect so one hiccup does
                        // not cascade into thousands of failures.
                        client = HttpClient::connect(addr).expect("reconnect load client");
                    }
                }
            }
            latencies
        }));
    }
    let mut latencies: Vec<f64> = Vec::with_capacity(CLIENT_THREADS * per_client);
    for w in workers {
        latencies.extend(w.join().expect("join load client"));
    }
    let secs = wall.elapsed().as_secs_f64();
    handle.shutdown();
    server_thread.join().expect("join server");

    let failed = failed.load(Ordering::SeqCst);
    let total = (CLIENT_THREADS * per_client) as u64;
    assert_eq!(failed, 0, "{failed} of {total} requests failed");
    assert_eq!(latencies.len() as u64, total);

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| latencies[((q * (latencies.len() - 1) as f64).round() as usize)
        .min(latencies.len() - 1)];
    let (p50, p99) = (pct(0.50), pct(0.99));
    let rps = total as f64 / secs;
    let batches = metrics.batches.load(Ordering::Relaxed);
    let rows = metrics.rows_transformed.load(Ordering::Relaxed);
    let rejected = metrics.rejected_overload.load(Ordering::Relaxed);

    println!("requests:    {total} ({failed} failed, {rejected} shed)");
    println!("wall:        {secs:.2}s  ->  {rps:.0} req/s");
    println!("latency:     p50 {:.3}ms  p99 {:.3}ms", p50 * 1e3, p99 * 1e3);
    println!(
        "batching:    {rows} rows in {batches} fused batches ({:.2} rows/batch)",
        rows as f64 / batches.max(1) as f64
    );

    let mut doc = Json::obj();
    doc.set("bench", jstr("serve"))
        .set("requests", jnum(total as f64))
        .set("failed", jnum(failed as f64))
        .set("client_threads", jnum(CLIENT_THREADS as f64))
        .set("server_threads", jnum(6.0))
        .set("wall_secs", jnum(secs))
        .set("requests_per_sec", jnum(rps))
        .set("latency_p50_ms", jnum(p50 * 1e3))
        .set("latency_p99_ms", jnum(p99 * 1e3))
        .set("batches", jnum(batches as f64))
        .set("rows_transformed", jnum(rows as f64))
        .set(
            "rows_per_batch",
            jnum(rows as f64 / batches.max(1) as f64),
        )
        .set("rejected_overload", jnum(rejected as f64));
    match write_bench_json("serve", &doc) {
        Ok(path) => println!("trajectory: {path}"),
        Err(e) => eprintln!("warning: could not write BENCH_serve.json: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
