#![allow(dead_code)] // each bench binary uses a subset of these helpers
//! Shared bench-scale config. `RCCA_BENCH_SCALE=full` reproduces the
//! EXPERIMENTS.md numbers; the default `quick` keeps `cargo bench` under a
//! few minutes on one core while preserving every qualitative shape.

use rcca::experiments::Scale;

pub fn bench_scale() -> Scale {
    match std::env::var("RCCA_BENCH_SCALE").as_deref() {
        Ok("full") => Scale::default(), // n=30k, d=4096, k=60
        Ok("tiny") => Scale::tiny(),
        _ => Scale {
            n: 8_000,
            dims: 1024,
            topics: 64,
            k: 30,
            p_small: 20,
            p_large: 120,
            nu: 0.01,
            test_fraction: 0.1,
            seed: 0xbe9c4,
            ..Scale::default()
        },
    }
}

/// Workload for the generalization experiments (Table 2b, Figure 3):
/// Scale::generalization() reproduces the paper's overfitting regime
/// (raw counts, weak-tail correlations, large d/n — DESIGN.md §3).
pub fn gen_scale() -> Scale {
    match std::env::var("RCCA_BENCH_SCALE").as_deref() {
        Ok("tiny") => Scale::tiny(),
        _ => Scale::generalization(),
    }
}

pub fn report_dir() -> String {
    std::env::var("RCCA_REPORT_DIR").unwrap_or_else(|_| "reports".to_string())
}

pub fn emit(report: &rcca::bench::Report) {
    println!("{}", report.render());
    match report.write_json(&report_dir()) {
        Ok(p) => println!("json: {p}\n"),
        Err(e) => eprintln!("warning: could not write json: {e}"),
    }
}
