//! Bench E2 — regenerates Figure 2a: Σ of first k canonical correlations
//! vs (q, p), with the Horst-120-pass dashed reference.

mod common;

use rcca::experiments::{e2_sweep, Workload};
use rcca::util::timer::Timer;

fn main() {
    let scale = common::bench_scale();
    let k = scale.k;
    println!("# Figure 2a bench (n={}, d={}, k={k})\n", scale.n, scale.dims);
    let workload = Workload::generate(scale);

    let ps: Vec<usize> = vec![
        workload.scale.p_small / 2,
        workload.scale.p_small,
        workload.scale.p_large / 2,
        workload.scale.p_large,
    ];
    let qs = vec![0usize, 1, 2, 3];
    let t = Timer::start();
    let res = e2_sweep::run(&workload, &qs, &ps, 120).expect("sweep");
    println!("sweep wall time: {:.1}s\n", t.secs());
    common::emit(&e2_sweep::report(&res, k));

    match e2_sweep::check_shape(&res, 0.05 * res.horst_objective.max(1.0)) {
        Ok(()) => println!("shape check: PASS (monotone in p and q; rcca approaches Horst from below)"),
        Err(m) => println!("shape check: DEVIATION — {m}"),
    }
}
