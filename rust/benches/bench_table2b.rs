//! Bench E3 — regenerates Table 2b: running times, train/test canonical
//! correlations for RandomizedCCA (q,p grid), Horst (same ν), Horst (best
//! ν), and Horst+rcca, including the pass-count-to-accuracy comparison.

mod common;

use rcca::experiments::{e3_table, Workload};
use rcca::util::timer::Timer;

fn main() {
    let scale = common::gen_scale();
    println!("# Table 2b bench (n={}, d={}, k={})\n", scale.n, scale.dims, scale.k);
    let workload = Workload::generate(scale);
    let cfg = e3_table::TableConfig::scaled(&workload);
    let t = Timer::start();
    let res = e3_table::run(&workload, &cfg).expect("table");
    println!("table wall time: {:.1}s\n", t.secs());
    common::emit(&e3_table::report(&res));

    // Paper-shape checks.
    let row = |label: &str| {
        res.rows
            .iter()
            .find(|r| r.label == label)
            .unwrap_or_else(|| panic!("missing row {label}"))
    };
    let horst_same = row("Horst (same nu)");
    let horst_best = row("Horst (best nu)");
    let rcca_rows: Vec<_> = res.rows.iter().filter(|r| r.label == "rcca").collect();
    let best_rcca_test = rcca_rows.iter().map(|r| r.test).fold(f64::MIN, f64::max);

    let mut ok = true;
    // 1. Horst (same ν) overfits: its train-test gap exceeds rcca's best.
    let rcca_gap = rcca_rows
        .iter()
        .map(|r| r.train - r.test)
        .fold(f64::MIN, f64::max);
    if horst_same.train - horst_same.test <= rcca_gap {
        println!("shape DEVIATION: Horst(same nu) gap not larger than rcca's");
        ok = false;
    }
    // 2. Best-ν Horst fixes the test objective (close to or above rcca's).
    if horst_best.test < best_rcca_test * 0.9 {
        println!("shape DEVIATION: Horst(best nu) test far below rcca");
        ok = false;
    }
    // 3. Warm start no slower than cold to the same accuracy.
    if res.passes_warm_to_target > res.passes_cold_to_target {
        println!(
            "shape DEVIATION: warm {} > cold {} passes",
            res.passes_warm_to_target, res.passes_cold_to_target
        );
        ok = false;
    }
    // 4. Time grows with q at fixed p.
    let times: Vec<f64> = rcca_rows
        .iter()
        .filter(|r| r.p == Some(workload.scale.p_large))
        .map(|r| r.secs)
        .collect();
    // Generous 2x tolerance: single-core wall times have multi-second
    // scheduling spikes; the content columns are what the table pins.
    if times.windows(2).any(|w| w[1] < w[0] * 0.5) {
        println!("shape DEVIATION: time not increasing with q: {times:?}");
        ok = false;
    }
    println!(
        "shape check: {}",
        if ok { "PASS (overfit gap, best-nu recovery, warm-start wins, time↑q)" } else { "see deviations above" }
    );
}
