//! Micro-benches (P1–P6): engine and substrate hot paths.
//!
//!   P1  GEMM roofline — f32 dense matmul GFLOP/s (the native final-pass core)
//!   P2  sparse-native vs dense-PJRT chunk crossover (the engine choice)
//!   P3  hashing + generator throughput (data-plane cost)
//!   P4  coordinator overhead — pass cost vs raw engine cost, pool latency
//!   P5  sparse kernels — scalar baselines vs the panel-blocked/fused
//!       `sparse::kernels` twins, incl. the power-chunk path and the serve
//!       transform (GFLOP/s per kernel)
//!   P6  out-of-core streaming — uncached end-to-end pass wall-time:
//!       legacy allocating loader vs pooled blocking loader vs the
//!       prefetch pipeline (I/O-overlap ratio feeds a bench-check gate)
//!
//! These feed EXPERIMENTS.md §Perf (before/after iteration log). Every
//! measured section also lands in `BENCH_micro.json` at the repo root so
//! perf is tracked machine-readably across PRs; CI compares it against
//! `BENCH_micro.baseline.json` with `repro bench-check`. Set
//! `RCCA_BENCH_SHORT=1` for the fast smoke configuration.

mod common;

use rcca::bench::{bench_fn, write_bench_json, Stats};
use rcca::data::synthparl::{SynthParl, SynthParlConfig};
use rcca::data::TwoViewChunk;
use rcca::linalg::gemm::{sgemm_nn, sgemm_tn};
use rcca::linalg::Mat;
use rcca::runtime::{mat_to_f32, ChunkEngine, ChunkMirror, NativeEngine, Workspace};
use rcca::sparse::kernels;
use rcca::util::json::Json;
use rcca::util::pool::Pool;
use rcca::util::rng::Rng;
use std::path::Path;

/// Accumulates `name -> Stats` entries for the BENCH_micro.json trajectory.
struct Trajectory(Json);

impl Trajectory {
    fn new() -> Trajectory {
        Trajectory(Json::obj())
    }

    fn record(&mut self, name: &str, stats: &Stats) {
        self.0.set(name, stats.to_json());
    }
}

fn main() {
    println!("# micro benches (P1–P5)\n");
    let mut traj = Trajectory::new();
    p1_gemm(&mut traj);
    p2_engines(&mut traj);
    p3_dataplane(&mut traj);
    p4_coordinator(&mut traj);
    p5_sparse_kernels(&mut traj);
    p6_streaming(&mut traj);
    let mut doc = Json::obj();
    doc.set("bench", rcca::util::json::jstr("micro"));
    doc.set("sections", traj.0);
    match write_bench_json("micro", &doc) {
        Ok(path) => println!("trajectory: {path}"),
        Err(e) => eprintln!("warning: could not write BENCH_micro.json: {e}"),
    }
}

fn p1_gemm(traj: &mut Trajectory) {
    println!("## P1: f32 GEMM");
    let mut rng = Rng::new(1);
    for &(m, k, n) in &[(256usize, 1024usize, 160usize), (256, 4096, 160), (512, 512, 512)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut c = vec![0f32; m * n];
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let stats = bench_fn(&format!("sgemm_nn {m}x{k}x{n}"), || {
            c.fill(0.0);
            sgemm_nn(m, k, n, &a, &b, &mut c);
        });
        println!("    -> {:.2} GFLOP/s", flops / stats.p50 / 1e9);
        traj.record(&format!("sgemm_nn_{m}x{k}x{n}"), &stats);
        let mut ct = vec![0f32; k.min(1024) * n];
        let kt = k.min(1024);
        let at: Vec<f32> = (0..m * kt).map(|_| rng.normal() as f32).collect();
        let bt: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let flops_t = 2.0 * m as f64 * kt as f64 * n as f64;
        let stats = bench_fn(&format!("sgemm_tn {m}x{kt}x{n}"), || {
            ct.fill(0.0);
            sgemm_tn(m, kt, n, &at, &bt, &mut ct);
        });
        println!("    -> {:.2} GFLOP/s", flops_t / stats.p50 / 1e9);
        // Keyed on the original k too: kt clamps to 1024, so two sweep
        // cases share the same (m, kt, n) shape and would collide.
        traj.record(&format!("sgemm_tn_{m}x{kt}x{n}_k{k}"), &stats);
    }
    println!();
}

fn bench_chunk(dims: usize, mean_len: f64) -> TwoViewChunk {
    let d = SynthParl::generate(SynthParlConfig {
        n: 256,
        dims,
        topics: 16,
        words_per_topic: 20,
        background_words: 64,
        mean_len,
        seed: 3,
        ..Default::default()
    });
    TwoViewChunk { a: d.a, b: d.b }
}

fn p2_engines(traj: &mut Trajectory) {
    println!("## P2: chunk engines — sparse-native vs dense-XLA (PJRT)");
    let have_artifacts = Path::new("artifacts/manifest.json").exists();
    let native = NativeEngine::new();
    let pjrt = if have_artifacts {
        match rcca::runtime::PjrtEngine::open(Path::new("artifacts")) {
            Ok(e) => Some(e),
            Err(e) => {
                println!("  (pjrt unavailable: {e})");
                None
            }
        }
    } else {
        println!("  (artifacts missing; run `make artifacts` for the PJRT side)");
        None
    };

    // Density sweep: hashed BoW is ~0.4% dense at mean_len 16 / d 4096; at
    // shorter docs the native path wins harder. The artifact d=256 grid is
    // used for the PJRT side (r=32), matching chunk m=64.
    for &mean_len in &[8.0f64, 32.0, 128.0] {
        let chunk = {
            let d = SynthParl::generate(SynthParlConfig {
                n: 64,
                dims: 256,
                topics: 8,
                words_per_topic: 16,
                background_words: 32,
                mean_len,
                seed: 5,
                ..Default::default()
            });
            TwoViewChunk { a: d.a, b: d.b }
        };
        let density = chunk.a.density();
        let mut rng = Rng::new(7);
        let qa = mat_to_f32(&Mat::randn(256, 32, &mut rng));
        let qb = mat_to_f32(&Mat::randn(256, 32, &mut rng));
        let sn = bench_fn(&format!("native power_chunk d=256 r=32 density={density:.3}"), || {
            native.power_chunk(&chunk, &qa, &qb, 32).unwrap();
        });
        traj.record(&format!("native_power_chunk_mean_len_{mean_len}"), &sn);
        if let Some(p) = &pjrt {
            let sp = bench_fn(&format!("pjrt   power_chunk d=256 r=32 density={density:.3}"), || {
                p.power_chunk(&chunk, &qa, &qb, 32).unwrap();
            });
            println!(
                "    -> native/pjrt p50 ratio: {:.2} (native {} at this density)",
                sp.p50 / sn.p50,
                if sp.p50 > sn.p50 { "wins" } else { "loses" }
            );
        }
    }
    println!();
}

fn p3_dataplane(traj: &mut Trajectory) {
    println!("## P3: data plane");
    let stats = bench_fn("synthparl generate+hash n=2000 d=2048", || {
        let _ = bench_chunk(2048, 16.0);
        // bench_chunk generates 256 rows; generate a bigger one inline:
    });
    traj.record("synthparl_generate_hash", &stats);
    let mut chunk = bench_chunk(2048, 16.0);
    let rows = chunk.rows();
    let nnz = chunk.a.nnz();
    let stats = bench_fn("csr densify 256x2048", || {
        let mut buf = vec![0f32; rows * 2048];
        chunk.a.densify_rows(0, rows, &mut buf);
    });
    println!(
        "    -> {:.1} MB/s densified ({nnz} nnz)",
        (rows * 2048 * 4) as f64 / stats.p50 / 1e6
    );
    traj.record("csr_densify_256x2048", &stats);
    let enc = rcca::data::shards::encode_shard(&chunk);
    println!("  shard encode: {} bytes for {} rows", enc.len(), rows);
    let stats = bench_fn("shard decode+validate", || {
        let _ = rcca::data::shards::decode_shard(&enc).unwrap();
    });
    println!(
        "    -> {:.1} MB/s decode",
        enc.len() as f64 / stats.p50 / 1e6
    );
    traj.record("shard_decode_validate", &stats);
    chunk.a.values[0] += 0.0; // keep mutable binding honest
    println!();
}

/// Pre-change scalar power chunk: the exact shape of the old
/// `NativeEngine::power_chunk` — four CSR walks through the scalar `Csr`
/// kernels plus four fresh buffers per call. Kept here as the measured
/// baseline the panel/fused path is gated against (≥1.5× target, see
/// EXPERIMENTS.md §Perf).
fn scalar_power_chunk(chunk: &TwoViewChunk, qa32: &[f32], qb32: &[f32], r: usize) -> (Mat, Mat) {
    let m = chunk.rows();
    let (da, db) = (chunk.a.cols, chunk.b.cols);
    let mut bq = vec![0f32; m * r];
    chunk.b.times_dense(qb32, r, &mut bq);
    let mut ya = vec![0f64; da * r];
    chunk.a.add_t_times_dense(&bq, r, &mut ya);
    let mut aq = vec![0f32; m * r];
    chunk.a.times_dense(qa32, r, &mut aq);
    let mut yb = vec![0f64; db * r];
    chunk.b.add_t_times_dense(&aq, r, &mut yb);
    (Mat::from_vec(da, r, ya), Mat::from_vec(db, r, yb))
}

fn p5_sparse_kernels(traj: &mut Trajectory) {
    println!("## P5: panel-blocked sparse kernels vs scalar baselines");
    let d = 4096usize;
    let r = 64usize;
    let chunk = bench_chunk(d, 16.0);
    let m = chunk.rows();
    let nnz = chunk.a.nnz();
    let mut rng = Rng::new(17);
    let qa = mat_to_f32(&Mat::randn(d, r, &mut rng));
    let qb = mat_to_f32(&Mat::randn(d, r, &mut rng));
    let gflops = |flops: f64, s: &Stats| flops / s.p50 / 1e9;

    // Gather: P = A·Q.
    let flops_gather = 2.0 * nnz as f64 * r as f64;
    let mut p = vec![0f32; m * r];
    let s = bench_fn(&format!("times_dense scalar {m}x{d} r={r}"), || {
        chunk.a.times_dense(&qa, r, &mut p);
    });
    println!("    -> {:.2} GFLOP/s", gflops(flops_gather, &s));
    traj.record("sparse_times_dense_scalar", &s);
    let s = bench_fn(&format!("times_dense panel  {m}x{d} r={r}"), || {
        kernels::times_dense(&chunk.a, &qa, r, &mut p);
    });
    println!("    -> {:.2} GFLOP/s", gflops(flops_gather, &s));
    traj.record("sparse_times_dense_panel", &s);

    // Scatter: Y += AᵀM (f64 accumulators).
    let mbuf = mat_to_f32(&Mat::randn(m, r, &mut rng));
    let mut y = vec![0f64; d * r];
    let s = bench_fn(&format!("scatter scalar     {m}x{d} r={r}"), || {
        chunk.a.add_t_times_dense(&mbuf, r, &mut y);
    });
    println!("    -> {:.2} GFLOP/s", gflops(flops_gather, &s));
    traj.record("sparse_scatter_scalar", &s);
    let s = bench_fn(&format!("scatter panel      {m}x{d} r={r}"), || {
        kernels::add_t_times_dense(&chunk.a, &mbuf, r, &mut y);
    });
    println!("    -> {:.2} GFLOP/s", gflops(flops_gather, &s));
    traj.record("sparse_scatter_panel", &s);

    // The power-chunk path: pre-change scalar baseline vs fused+workspace
    // vs mirrored scatter. The ≥1.5× acceptance gate compares the first
    // two entries of this block.
    let flops_power = 2.0 * (chunk.a.nnz() + chunk.b.nnz()) as f64 * r as f64 * 2.0;
    let eng = NativeEngine::new();
    let s_scalar = bench_fn(&format!("power_chunk scalar (pre-change) r={r}"), || {
        let _ = scalar_power_chunk(&chunk, &qa, &qb, r);
    });
    println!("    -> {:.2} GFLOP/s", gflops(flops_power, &s_scalar));
    traj.record("power_chunk_scalar", &s_scalar);
    let mut ws = Workspace::new();
    let s_fused = bench_fn(&format!("power_chunk fused+workspace     r={r}"), || {
        ws.begin_power(d, d, r);
        eng.power_chunk_ws(chunk.view(), None, &qa, &qb, r, &mut ws).unwrap();
    });
    println!(
        "    -> {:.2} GFLOP/s ({:.2}x vs scalar)",
        gflops(flops_power, &s_fused),
        s_scalar.p50 / s_fused.p50
    );
    traj.record("power_chunk_fused", &s_fused);
    let mir = ChunkMirror::build(&chunk);
    let s_mir = bench_fn(&format!("power_chunk mirrored scatter    r={r}"), || {
        ws.begin_power(d, d, r);
        eng.power_chunk_ws(chunk.view(), Some(&mir), &qa, &qb, r, &mut ws)
            .unwrap();
    });
    println!(
        "    -> {:.2} GFLOP/s ({:.2}x vs scalar)",
        gflops(flops_power, &s_mir),
        s_scalar.p50 / s_mir.p50
    );
    traj.record("power_chunk_mirrored", &s_mir);

    // Serve transform: k-narrow projection, f64 `times_mat` (pre-change
    // serving path) vs the blocked f32 kernel with f64 output accumulation.
    let k = 8usize;
    let proj = Mat::randn(d, k, &mut rng);
    let proj32 = mat_to_f32(&proj);
    let flops_serve = 2.0 * nnz as f64 * k as f64;
    let s = bench_fn(&format!("serve transform f64 times_mat  k={k}"), || {
        let _ = chunk.a.times_mat(&proj);
    });
    println!("    -> {:.2} GFLOP/s", gflops(flops_serve, &s));
    traj.record("serve_transform_f64", &s);
    let mut out = vec![0f64; m * k];
    let s = bench_fn(&format!("serve transform f32 panel      k={k}"), || {
        kernels::times_dense_acc64(&chunk.a, &proj32, k, &mut out);
    });
    println!("    -> {:.2} GFLOP/s", gflops(flops_serve, &s));
    traj.record("serve_transform_f32", &s);
    println!();
}

/// P6: the paper's out-of-core scenario end to end — every pass re-reads
/// the shard store from disk. Three loaders over the identical compute:
///
///   * `stream_pass_legacy`     — the pre-change path: blocking
///     `ShardStore::load` (allocating decode) + owned `slice_rows` chunks;
///   * `stream_pass_blocking`   — pooled buffers + in-place decode +
///     borrowed chunk views, but reads on the compute thread (depth 0);
///   * `stream_pass_prefetched` — same, with the I/O thread reading and
///     CRC-verifying the next shards while kernels run;
///   * `stream_pass_prefetched_traced` — the prefetch pipeline again with
///     the telemetry flight recorder installed, bounding per-span recorder
///     overhead on the hottest path (<2% target, EXPERIMENTS.md §Telemetry).
///
/// All loaders produce bitwise-identical passes (coordinator tests pin it);
/// only wall-time differs. `repro bench-check --gates` arms
/// `stream_pass_prefetched/stream_pass_blocking` as a within-run ratio so
/// CI catches the pipeline ever becoming a pessimization, and the traced
/// section re-runs the same two gates so tracing can never silently eat the
/// overlap win. `workers` is pinned to 1 so the measured overlap comes from
/// the I/O thread alone.
fn p6_streaming(traj: &mut Trajectory) {
    println!("## P6: out-of-core streaming — uncached end-to-end pass wall-time");
    use rcca::cca::pass::PassEngine;
    use rcca::coordinator::{ShardedPass, ShardedPassConfig};
    use rcca::data::shards::ShardStore;
    use std::sync::Arc;
    let short = rcca::bench::short_mode();
    let (n, dims, r) = if short { (4096usize, 512usize, 32usize) } else { (16384, 2048, 64) };
    let d = SynthParl::generate(SynthParlConfig {
        n,
        dims,
        topics: 16,
        words_per_topic: 20,
        background_words: 64,
        mean_len: 16.0,
        seed: 19,
        ..Default::default()
    });
    let dir = std::env::temp_dir().join("rcca_bench_micro_p6");
    let _ = std::fs::remove_dir_all(&dir);
    let mut w = rcca::data::shards::ShardWriter::create(&dir, 1024).unwrap();
    w.write_dataset(&d.a, &d.b).unwrap();
    let store = ShardStore::open(&dir).unwrap();
    let mut rng = Rng::new(23);
    let qa = Mat::randn(dims, r, &mut rng);
    let qb = Mat::randn(dims, r, &mut rng);
    let (qa32, qb32) = (mat_to_f32(&qa), mat_to_f32(&qb));
    let chunk_rows = 256usize;

    // Legacy loader: exactly the pre-change uncached shard task.
    let eng = NativeEngine::new();
    let s_legacy = bench_fn("stream pass: legacy allocating loader", || {
        for i in 0..store.shards {
            let data = store.load(i).unwrap();
            let rows = data.rows();
            let mut ws = Workspace::new();
            ws.begin_power(dims, dims, r);
            let mut lo = 0;
            while lo < rows {
                let hi = (lo + chunk_rows).min(rows);
                let chunk = rcca::data::TwoViewChunk {
                    a: data.a.slice_rows(lo, hi),
                    b: data.b.slice_rows(lo, hi),
                };
                eng.power_chunk_ws(chunk.view(), None, &qa32, &qb32, r, &mut ws)
                    .unwrap();
                lo = hi;
            }
            let _ = ws.take();
        }
    });
    traj.record("stream_pass_legacy", &s_legacy);

    let mk = |depth: usize, io: usize| {
        ShardedPass::new(
            store.clone(),
            Arc::new(NativeEngine::new()),
            ShardedPassConfig {
                workers: 1,
                chunk_rows,
                cache_shards: false,
                prefetch_depth: depth,
                io_threads: io,
                ..Default::default()
            },
        )
    };
    let mut blocking = mk(0, 1);
    let s_block = bench_fn("stream pass: pooled blocking loader  (depth 0)", || {
        let _ = blocking.power_pass(&qa, &qb);
    });
    traj.record("stream_pass_blocking", &s_block);
    let mut prefetched = mk(2, 1);
    let s_pre = bench_fn("stream pass: prefetch pipeline (depth 2, io 1)", || {
        let _ = prefetched.power_pass(&qa, &qb);
    });
    traj.record("stream_pass_prefetched", &s_pre);
    println!(
        "    -> I/O overlap: {:.2}x vs pooled blocking, {:.2}x vs legacy loader \
         ({} shards, d={dims}, r={r})",
        s_block.p50 / s_pre.p50,
        s_legacy.p50 / s_pre.p50,
        store.shards
    );

    // The identical prefetched pass with the flight recorder live: every
    // pass/shard_task/load/engine/reduce span is recorded for real. The
    // bench-check gates hold this section to the same ratios as the
    // untraced pipeline, so recorder overhead is capped by CI.
    rcca::telemetry::install_default();
    let s_traced = bench_fn("stream pass: prefetched + flight recorder on", || {
        let _ = prefetched.power_pass(&qa, &qb);
    });
    rcca::telemetry::disable();
    let trace = rcca::telemetry::drain();
    traj.record("stream_pass_prefetched_traced", &s_traced);
    println!(
        "    -> recorder overhead: {:+.1}% vs untraced ({} spans buffered, {} dropped)",
        (s_traced.p50 / s_pre.p50 - 1.0) * 100.0,
        trace.spans.len(),
        trace.dropped
    );
    let _ = std::fs::remove_dir_all(&dir);
    println!();
}

fn p4_coordinator(traj: &mut Trajectory) {
    println!("## P4: coordinator overhead");
    // Pool task round-trip latency.
    let pool = Pool::new(2, 64);
    let stats = bench_fn("pool submit+wait_idle x64 noop tasks", || {
        for _ in 0..64 {
            pool.submit(|| {});
        }
        pool.wait_idle();
    });
    println!(
        "    -> {:.2} µs/task scheduling overhead ({} still active)",
        stats.p50 / 64.0 * 1e6,
        pool.active()
    );
    traj.record("pool_submit_wait_idle_x64", &stats);

    // Full pass cost vs sum of raw engine chunk costs, through the api
    // engine (same coordinator underneath, metrics exposed via
    // `Engine::metrics`).
    use rcca::api::{Engine, ShardedOpts};
    use rcca::data::shards::ShardWriter;
    let d = SynthParl::generate(SynthParlConfig {
        n: 4096,
        dims: 1024,
        topics: 16,
        words_per_topic: 20,
        background_words: 64,
        mean_len: 16.0,
        seed: 11,
        ..Default::default()
    });
    let dir = std::env::temp_dir().join("rcca_bench_micro");
    let _ = std::fs::remove_dir_all(&dir);
    let mut w = ShardWriter::create(&dir, 512).unwrap();
    w.write_dataset(&d.a, &d.b).unwrap();
    let mut sharded = Engine::sharded(
        &dir,
        ShardedOpts {
            workers: 2,
            chunk_rows: 256,
            ..Default::default()
        },
    )
    .unwrap();
    let mut rng = Rng::new(13);
    let qa = Mat::randn(1024, 64, &mut rng);
    let qb = Mat::randn(1024, 64, &mut rng);
    use rcca::cca::pass::PassEngine;
    let stats = bench_fn("coordinator power_pass n=4096 d=1024 r=64", || {
        let _ = sharded.power_pass(&qa, &qb);
    });
    traj.record("coordinator_power_pass_n4096_d1024_r64", &stats);
    let m = sharded.metrics().expect("sharded engine has metrics").snapshot();
    println!(
        "    -> pass p50 {:.1}ms; engine share {:.0}%; metrics {m}",
        stats.p50 * 1e3,
        100.0 * m.get("engine_secs").unwrap().as_f64().unwrap()
            / (m.get("engine_secs").unwrap().as_f64().unwrap()
                + m.get("load_secs").unwrap().as_f64().unwrap()
                + m.get("reduce_secs").unwrap().as_f64().unwrap()).max(1e-9)
    );
    let _ = std::fs::remove_dir_all(&dir);
    println!();
}
