//! Bench E1 — regenerates Figure 1: spectrum of (1/n)AᵀB via two-pass
//! randomized SVD, with wall-time measurement of the estimator.

mod common;

use rcca::experiments::{e1_spectrum, Workload};
use rcca::util::timer::Timer;

fn main() {
    let scale = common::bench_scale();
    println!(
        "# Figure 1 bench (n={}, d={}, scale via RCCA_BENCH_SCALE)\n",
        scale.n, scale.dims
    );
    let top = (scale.dims / 8).clamp(32, 512);
    let t = Timer::start();
    let workload = Workload::generate(scale);
    println!("workload generation: {:.1}s", t.secs());

    let mut engine = workload.train_engine();
    let t = Timer::start();
    let res = e1_spectrum::run(&mut engine, &workload, top, top / 4, 0x57ec);
    println!(
        "two-pass randomized SVD (top {top} values): {:.2}s, {} passes\n",
        t.secs(),
        res.passes
    );
    common::emit(&e1_spectrum::report(&res, (top / 32).max(1)));

    // Paper-shape assertions (who wins / what decays), not absolute values.
    assert_eq!(res.passes, 2, "Figure 1 estimator must use two passes");
    assert!(
        res.loglog_slope < -0.2,
        "spectrum must show power-law decay (slope {})",
        res.loglog_slope
    );
    println!("shape check: PASS (two passes, power-law decay slope {:.3})", res.loglog_slope);
}
