//! Bench E4 — regenerates Figure 3: train/test objective vs ν for
//! RandomizedCCA (q=2, p=p_large) and Horst (120-pass budget).

mod common;

use rcca::experiments::{e4_nu, Workload};
use rcca::util::timer::Timer;

fn main() {
    let scale = common::gen_scale();
    println!("# Figure 3 bench (n={}, d={}, k={})\n", scale.n, scale.dims, scale.k);
    let workload = Workload::generate(scale);
    let nus = [0.0005, 0.002, 0.01, 0.05, 0.2, 1.0];
    let (q, p, budget) = (2usize, workload.scale.p_large, 120usize);
    let t = Timer::start();
    let pts = e4_nu::run(&workload, &nus, q, p, budget).expect("nu sweep");
    println!("sweep wall time: {:.1}s\n", t.secs());
    common::emit(&e4_nu::report(&pts, q, p, budget));
    match e4_nu::check_shape(&pts) {
        Ok(()) => println!("shape check: PASS (Horst overfits at small nu; rcca robust)"),
        Err(m) => println!("shape check: DEVIATION — {m}"),
    }
}
